//! Synthetic CIFAR-like dataset (DESIGN.md substitution table: no network
//! access, so the learnability experiments run on a deterministic
//! 10-class, 3x32x32 image distribution with class-conditional structure).
//!
//! Each class is defined by a smooth random "prototype" image (a mixture
//! of oriented sinusoidal gratings with class-specific frequencies and a
//! class-specific color cast); samples are the prototype plus pixel noise
//! and a random global intensity jitter.  This is hard enough that a
//! linear model underperforms a CNN, and easy enough that the paper's 1X
//! net trains to high accuracy in tens of epochs.

use crate::fixed::{quantize, FA};
use crate::nn::tensor::Tensor;
use crate::nn::testutil::Lcg;

/// A labelled fixed-point image (values at FA, roughly in [-1, 1]).
#[derive(Debug, Clone)]
pub struct Sample {
    pub image: Tensor,
    pub label: usize,
}

/// Deterministic synthetic dataset generator.
pub struct Synthetic {
    prototypes: Vec<Vec<f64>>, // nclass x (c*h*w)
    pub nclass: usize,
    pub shape: (usize, usize, usize),
    /// The generator seed.  Together with a sample index this fully
    /// determines every sample, so `(seed, index)` is the whole dataset
    /// cursor a training checkpoint needs to record (see `ckpt`).
    pub seed: u64,
    noise: f64,
}

impl Synthetic {
    /// Build the class prototypes from `seed`.  `noise` is the per-pixel
    /// noise amplitude relative to the prototype contrast (0.3 default).
    pub fn new(nclass: usize, shape: (usize, usize, usize), seed: u64,
               noise: f64) -> Synthetic {
        let (c, h, w) = shape;
        let mut rng = Lcg::new(seed ^ 0xDA7A5E7);
        let mut prototypes = Vec::with_capacity(nclass);
        for _ in 0..nclass {
            // 3 oriented gratings + per-channel color cast
            let mut gratings = Vec::new();
            for _ in 0..3 {
                let fx = 0.2 + 0.8 * rng.unit();
                let fy = 0.2 + 0.8 * rng.unit();
                let phase = rng.unit() * std::f64::consts::TAU;
                let amp = 0.3 + 0.4 * rng.unit();
                gratings.push((fx, fy, phase, amp));
            }
            let casts: Vec<f64> =
                (0..c).map(|_| 0.6 * (rng.unit() - 0.5)).collect();
            let mut proto = vec![0.0; c * h * w];
            for ci in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        let mut v = casts[ci];
                        for &(fx, fy, phase, amp) in &gratings {
                            v += amp
                                * ((fx * x as f64 + fy * y as f64
                                    + phase
                                    + ci as f64 * 0.7)
                                    .sin());
                        }
                        proto[(ci * h + y) * w + x] = 0.4 * v;
                    }
                }
            }
            prototypes.push(proto);
        }
        Synthetic { prototypes, nclass, shape, seed, noise }
    }

    /// Paper-shaped default: 10 classes, 3x32x32.
    pub fn cifar_like(seed: u64) -> Synthetic {
        Synthetic::new(10, (3, 32, 32), seed, 0.3)
    }

    /// Deterministically generate sample `index` (any index is valid; the
    /// dataset is a pure function of (seed, index)).
    pub fn sample(&self, index: u64) -> Sample {
        let mut rng = Lcg::new(index.wrapping_mul(0x5851F42D) ^ 0xC0FFEE);
        let label = (index as usize) % self.nclass;
        let proto = &self.prototypes[label];
        let jitter = 1.0 + 0.2 * (rng.unit() - 0.5);
        let data: Vec<i32> = proto
            .iter()
            .map(|&p| {
                let v = jitter * p + self.noise * (rng.unit() - 0.5);
                quantize(v, FA)
            })
            .collect();
        let (c, h, w) = self.shape;
        Sample { image: Tensor::from_vec(&[c, h, w], data), label }
    }

    /// A batch of consecutive samples starting at `start`.
    pub fn batch(&self, start: u64, n: usize) -> Vec<Sample> {
        (0..n as u64).map(|i| self.sample(start + i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_index() {
        let d = Synthetic::cifar_like(1);
        let a = d.sample(12);
        let b = d.sample(12);
        assert_eq!(a.image, b.image);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn labels_cycle_all_classes() {
        let d = Synthetic::cifar_like(1);
        let labels: Vec<usize> =
            (0..20).map(|i| d.sample(i).label).collect();
        for c in 0..10 {
            assert!(labels.contains(&c));
        }
    }

    #[test]
    fn values_in_fixed_range() {
        let d = Synthetic::cifar_like(2);
        for i in 0..8 {
            let s = d.sample(i);
            assert_eq!(s.image.shape(), &[3, 32, 32]);
            // roughly within ±2.0 at FA
            assert!(s.image.max_abs() <= 2 * (1 << FA));
        }
    }

    #[test]
    fn classes_are_separated() {
        // nearest-prototype classification of fresh samples should beat
        // chance by a wide margin — the dataset must be learnable
        let d = Synthetic::cifar_like(3);
        let mut correct = 0;
        let total = 100;
        for i in 0..total {
            let s = d.sample(1000 + i as u64);
            let mut best = (f64::MAX, 0usize);
            for (k, proto) in d.prototypes.iter().enumerate() {
                let dist: f64 = proto
                    .iter()
                    .zip(s.image.data())
                    .map(|(&p, &q)| {
                        let qf = f64::from(q) / f64::from(1 << FA);
                        (p - qf) * (p - qf)
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            if best.1 == s.label {
                correct += 1;
            }
        }
        assert!(correct > 80, "nearest-prototype acc {correct}/{total}");
    }

    #[test]
    fn different_seeds_give_different_tasks() {
        let a = Synthetic::cifar_like(1).sample(0);
        let b = Synthetic::cifar_like(99).sample(0);
        assert_ne!(a.image, b.image);
    }
}
