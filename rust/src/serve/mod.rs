//! The experiment service behind `stratus serve` — a crash-safe,
//! preemptive, multi-tenant queue of training runs.
//!
//! The paper's compiler turns one spec into one accelerator run; this
//! layer turns a *stream* of specs into scheduled runs.  Submissions
//! (spec JSON, plus an optional top-level `"priority"`) arrive
//! through a watched inbox directory or stdin lines ([`watch`]),
//! enter a durable priority queue of per-run state files
//! ([`queue`]), and are time-sliced by the scheduler ([`scheduler`]):
//! each admitted run trains for `slice_batches` batches
//! ([`crate::session::Session::begin_slice`] — `max_batches` as the
//! preemption point, checkpoint cadence pinned to the slice), then
//! the next queued run swaps in.  Every decision is streamed as one
//! strict JSON line ([`event`]).
//!
//! The whole service state lives on disk under one *serve root*:
//!
//! ```text
//! <root>/
//!   inbox/                    default watched submission dir
//!   runs/<id>/spec.json       normalized spec (ckpt dir redirected)
//!   runs/<id>/state.json      durable queue record (atomic writes)
//!   runs/<id>/ckpt/           the run's SCKP checkpoints
//!   failed/<name>[.reason]    rejected submissions + why
//!   events.jsonl              append-only JSON-lines audit trail
//! ```
//!
//! so `kill -9` of the daemon loses nothing: re-opening the root
//! requeues every mid-slice run and resumes it from its newest
//! checkpoint, bit-identically to a run that was never interrupted
//! (the same fingerprint machinery as `--resume`; asserted by
//! `tests/serve.rs` and the CI serve smoke step).

pub mod event;
pub mod queue;
pub mod scheduler;
pub mod watch;

pub use event::{read_events, EventLog, EVENTS_FILE};
pub use queue::{scan_states, RunPhase, RunState, ServeRoot};
pub use scheduler::{Scheduler, ServeConfig, Tick};
pub use watch::{list_submissions, parse_submission, SubmitError};
