//! The durable run queue: one directory per run under
//! `<serve-root>/runs/`, each holding the normalized spec, a small
//! JSON state file, and the run's checkpoint directory.
//!
//! Durability contract: `state.json` is the *only* queue metadata and
//! it is written atomically (tmp + fsync + rename + parent fsync,
//! the same discipline as [`crate::ckpt::Checkpoint::save_atomic`]),
//! so a `kill -9` at any instant leaves every run with either its
//! previous state or its new one — never a torn file.  Numeric truth
//! (params, optimizer state, cursor) lives in the checkpoint, which
//! has its own atomicity; the state file only has to be *consistent
//! enough to requeue*: a run found `running` at recovery simply
//! becomes `queued` again and resumes from its newest checkpoint.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::Json;
use crate::session::CKPT_FILE;

/// Per-run state file name (under `runs/<id>/`).
pub const STATE_FILE: &str = "state.json";
/// Normalized spec file name (under `runs/<id>/`).
pub const SPEC_FILE: &str = "spec.json";
/// Checkpoint subdirectory name (under `runs/<id>/`).
pub const CKPT_SUBDIR: &str = "ckpt";
/// Run directories live here.
pub const RUNS_DIR: &str = "runs";
/// Rejected submissions (plus `<name>.reason` files) land here.
pub const FAILED_DIR: &str = "failed";
/// Default watched submission directory.
pub const INBOX_DIR: &str = "inbox";

/// Where a run is in its lifecycle.  `Running` is only ever observed
/// on disk after a crash (the daemon marks a run `running` before its
/// slice and back to `queued`/`done`/`failed` after); recovery
/// demotes it to `Queued`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunPhase {
    Queued,
    Running,
    Done,
    Failed,
}

impl RunPhase {
    pub fn name(self) -> &'static str {
        match self {
            RunPhase::Queued => "queued",
            RunPhase::Running => "running",
            RunPhase::Done => "done",
            RunPhase::Failed => "failed",
        }
    }

    fn parse(s: &str) -> Option<RunPhase> {
        match s {
            "queued" => Some(RunPhase::Queued),
            "running" => Some(RunPhase::Running),
            "done" => Some(RunPhase::Done),
            "failed" => Some(RunPhase::Failed),
            _ => None,
        }
    }
}

/// One run's durable queue record.
#[derive(Debug, Clone)]
pub struct RunState {
    /// Directory name under `runs/`: `r<seq:04>-<sanitized stem>`.
    pub id: String,
    /// Admission order (monotone per serve root; the fairness
    /// tie-break).
    pub seq: u64,
    /// Higher runs first; equal priorities share slices fairly.
    pub priority: i64,
    /// The submission file name this run was admitted from (dedup key
    /// for the crash window between run-dir creation and inbox
    /// unlink).
    pub source: String,
    pub phase: RunPhase,
    /// Completed (recorded) slices.
    pub slices: u64,
    /// Batches executed across all recorded slices.
    pub batches: u64,
    /// Cursor snapshot after the last recorded slice (display /
    /// accounting only — the checkpoint is the numeric truth).
    pub epoch: u64,
    pub batch: u64,
    /// Target epoch count, denormalized from the spec for status
    /// rendering without a spec parse.
    pub epochs: u64,
    /// Failure reason, when `phase == Failed`.
    pub error: Option<String>,
}

impl RunState {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("id", Json::Str(self.id.clone()));
        put("seq", Json::Num(self.seq as f64));
        put("priority", Json::Num(self.priority as f64));
        put("source", Json::Str(self.source.clone()));
        put("phase", Json::Str(self.phase.name().to_string()));
        put("slices", Json::Num(self.slices as f64));
        put("batches", Json::Num(self.batches as f64));
        put("epoch", Json::Num(self.epoch as f64));
        put("batch", Json::Num(self.batch as f64));
        put("epochs", Json::Num(self.epochs as f64));
        if let Some(e) = &self.error {
            put("error", Json::Str(e.clone()));
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<RunState> {
        let m = j.as_obj().ok_or_else(|| {
            anyhow!("run state is not a JSON object")
        })?;
        let str_of = |k: &str| -> Result<String> {
            m.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("run state missing `{k}`"))
        };
        let num_of = |k: &str| -> Result<f64> {
            m.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("run state missing `{k}`"))
        };
        let phase_name = str_of("phase")?;
        let phase = RunPhase::parse(&phase_name).ok_or_else(|| {
            anyhow!("unknown run phase `{phase_name}`")
        })?;
        Ok(RunState {
            id: str_of("id")?,
            seq: num_of("seq")? as u64,
            priority: num_of("priority")? as i64,
            source: str_of("source")?,
            phase,
            slices: num_of("slices")? as u64,
            batches: num_of("batches")? as u64,
            epoch: num_of("epoch")? as u64,
            batch: num_of("batch")? as u64,
            epochs: num_of("epochs")? as u64,
            error: m.get("error")
                .and_then(Json::as_str)
                .map(str::to_string),
        })
    }

    /// Atomically persist this record as `dir/state.json` (see the
    /// module docs for the durability contract).
    pub fn save_atomic(&self, dir: &Path) -> Result<()> {
        let path = dir.join(STATE_FILE);
        let tmp = dir.join(format!("{STATE_FILE}.tmp"));
        {
            let mut f = fs::File::create(&tmp).with_context(|| {
                format!("creating {}", tmp.display())
            })?;
            f.write_all(self.to_json().pretty().as_bytes())
                .with_context(|| {
                    format!("writing {}", tmp.display())
                })?;
            f.sync_all().with_context(|| {
                format!("syncing {}", tmp.display())
            })?;
        }
        fs::rename(&tmp, &path).with_context(|| {
            format!("renaming {} -> {}", tmp.display(), path.display())
        })?;
        fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("syncing {}", dir.display()))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<RunState> {
        let path = dir.join(STATE_FILE);
        let text = fs::read_to_string(&path).with_context(|| {
            format!("reading {}", path.display())
        })?;
        let j = Json::parse(&text).with_context(|| {
            format!("parsing {}", path.display())
        })?;
        RunState::from_json(&j).with_context(|| {
            format!("loading {}", path.display())
        })
    }
}

/// The serve-root directory layout (see DESIGN.md §Experiment
/// service).  Opening creates the skeleton; every path accessor is a
/// pure join.
pub struct ServeRoot {
    root: PathBuf,
}

impl ServeRoot {
    pub fn open(root: &Path) -> Result<ServeRoot> {
        for sub in [RUNS_DIR, FAILED_DIR, INBOX_DIR] {
            let d = root.join(sub);
            fs::create_dir_all(&d).with_context(|| {
                format!("creating {}", d.display())
            })?;
        }
        Ok(ServeRoot { root: root.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.root
    }

    pub fn inbox_dir(&self) -> PathBuf {
        self.root.join(INBOX_DIR)
    }

    pub fn failed_dir(&self) -> PathBuf {
        self.root.join(FAILED_DIR)
    }

    pub fn run_dir(&self, id: &str) -> PathBuf {
        self.root.join(RUNS_DIR).join(id)
    }

    pub fn spec_path(&self, id: &str) -> PathBuf {
        self.run_dir(id).join(SPEC_FILE)
    }

    pub fn ckpt_dir(&self, id: &str) -> PathBuf {
        self.run_dir(id).join(CKPT_SUBDIR)
    }

    pub fn ckpt_path(&self, id: &str) -> PathBuf {
        self.ckpt_dir(id).join(CKPT_FILE)
    }

    /// Every run record under `runs/`, sorted by admission order.
    /// A run directory without a state file (a crash between `mkdir`
    /// and the first state write) is skipped: its submission was
    /// still in the inbox at that point, so it is re-admitted rather
    /// than lost.
    pub fn scan(&self) -> Result<Vec<RunState>> {
        scan_states(&self.root)
    }
}

/// Scan `root/runs/*/state.json` without creating anything — shared
/// by the scheduler's recovery pass and `report serve` / `--status`
/// (which must not mutate a root they merely inspect).
pub fn scan_states(root: &Path) -> Result<Vec<RunState>> {
    let runs = root.join(RUNS_DIR);
    if !runs.is_dir() {
        bail!("{} is not a serve root (no {RUNS_DIR}/ directory)",
              root.display());
    }
    let mut out = Vec::new();
    for entry in fs::read_dir(&runs).with_context(|| {
        format!("reading {}", runs.display())
    })? {
        let dir = entry?.path();
        if !dir.is_dir() {
            continue;
        }
        if !dir.join(STATE_FILE).is_file() {
            // crash window between run-dir creation and the first
            // state write: the submission file was still in the
            // inbox (it is unlinked only after the state lands), so
            // the half-made dir is inert leftovers, not a lost run
            continue;
        }
        out.push(RunState::load(&dir)?);
    }
    out.sort_by_key(|r| r.seq);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("stratus_q_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn state(id: &str, seq: u64) -> RunState {
        RunState {
            id: id.to_string(),
            seq,
            priority: -2,
            source: format!("{id}.json"),
            phase: RunPhase::Running,
            slices: 3,
            batches: 24,
            epoch: 1,
            batch: 2,
            epochs: 4,
            error: None,
        }
    }

    #[test]
    fn state_round_trips_and_writes_atomically() {
        let d = tmp("rt");
        let st = state("r0001-a", 1);
        st.save_atomic(&d).unwrap();
        assert!(!d.join(format!("{STATE_FILE}.tmp")).exists());
        let r = RunState::load(&d).unwrap();
        assert_eq!(r.id, st.id);
        assert_eq!(r.priority, -2);
        assert_eq!(r.phase, RunPhase::Running);
        assert_eq!((r.slices, r.batches, r.epoch, r.batch, r.epochs),
                   (3, 24, 1, 2, 4));
        assert_eq!(r.error, None);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn scan_sorts_by_seq_and_skips_stateless_dirs() {
        let root = tmp("scan");
        let sr = ServeRoot::open(&root).unwrap();
        for (id, seq) in [("r0002-b", 2), ("r0001-a", 1)] {
            let dir = sr.run_dir(id);
            std::fs::create_dir_all(&dir).unwrap();
            state(id, seq).save_atomic(&dir).unwrap();
        }
        // a half-created run dir (no state file yet) is skipped
        std::fs::create_dir_all(sr.run_dir("r0003-half")).unwrap();
        let runs = sr.scan().unwrap();
        let ids: Vec<&str> =
            runs.iter().map(|r| r.id.as_str()).collect();
        assert_eq!(ids, vec!["r0001-a", "r0002-b"]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn scan_refuses_a_non_serve_root() {
        let root = tmp("nonroot");
        let err = scan_states(&root).unwrap_err();
        assert!(format!("{err:#}").contains("not a serve root"),
                "{err:#}");
        let _ = std::fs::remove_dir_all(&root);
    }
}
