//! Append-only JSON-lines event stream for the experiment service.
//!
//! Every scheduling decision the daemon makes lands as one strict
//! JSON object per line in `<serve-root>/events.jsonl` (rendered with
//! [`Json::compact`], so every line re-parses) and, when the daemon
//! runs interactively, is echoed to stdout.  The log is the audit
//! trail the fairness and chaos tests assert slice ordering from, so
//! appends are fsync'd: an event that was observed was durably
//! recorded.
//!
//! Schema: every record carries `event` (the kind), `seq` (the
//! 0-based line number, monotone across daemon restarts) and
//! `unix_ms`; the remaining keys are per-kind (see DESIGN.md
//! §Experiment service for the full schema).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write as _};
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::jsonx::Json;

/// The event log's file name under the serve root.
pub const EVENTS_FILE: &str = "events.jsonl";

/// An open (append-mode) event stream.
pub struct EventLog {
    path: PathBuf,
    seq: u64,
    echo: bool,
}

impl EventLog {
    /// Open (or create) the log under `root`; `echo` additionally
    /// streams every line to stdout.  The next sequence number
    /// continues from the existing line count, so `seq` stays
    /// monotone across daemon restarts.
    pub fn open(root: &Path, echo: bool) -> Result<EventLog> {
        let path = root.join(EVENTS_FILE);
        let seq = match File::open(&path) {
            Ok(f) => BufReader::new(f).lines().count() as u64,
            Err(_) => 0,
        };
        Ok(EventLog { path, seq, echo })
    }

    /// Append one event. `fields` ride alongside the standard
    /// `event`/`seq`/`unix_ms` keys.
    pub fn emit(&mut self, event: &str, fields: &[(&str, Json)])
                -> Result<()> {
        let mut m = BTreeMap::new();
        m.insert("event".to_string(), Json::Str(event.to_string()));
        m.insert("seq".to_string(), Json::Num(self.seq as f64));
        let ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map_or(0.0, |d| d.as_millis() as f64);
        m.insert("unix_ms".to_string(), Json::Num(ms));
        for (k, v) in fields {
            m.insert((*k).to_string(), v.clone());
        }
        let line = Json::Obj(m).compact();
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| {
                format!("opening {}", self.path.display())
            })?;
        writeln!(f, "{line}").with_context(|| {
            format!("appending to {}", self.path.display())
        })?;
        f.sync_all().with_context(|| {
            format!("syncing {}", self.path.display())
        })?;
        self.seq += 1;
        if self.echo {
            println!("{line}");
        }
        Ok(())
    }
}

/// Parse every event recorded under `root` (a missing log is an empty
/// history, not an error — a serve root that never scheduled anything
/// has no events yet).
pub fn read_events(root: &Path) -> Result<Vec<Json>> {
    let path = root.join(EVENTS_FILE);
    let f = match File::open(&path) {
        Ok(f) => f,
        Err(_) => return Ok(Vec::new()),
    };
    let mut out = Vec::new();
    for line in BufReader::new(f).lines() {
        let line = line.with_context(|| {
            format!("reading {}", path.display())
        })?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(Json::parse(&line).with_context(|| {
            format!("parsing event line in {}", path.display())
        })?);
    }
    Ok(out)
}

/// Shorthand used across the serve modules for event fields.
pub(crate) fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

/// Shorthand: a numeric event field (u64 counters fit f64 exactly up
/// to 2^53, far beyond any slice count).
pub(crate) fn n(v: u64) -> Json {
    Json::Num(v as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("stratus_ev_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn events_append_and_read_back() {
        let root = tmp("rw");
        let mut log = EventLog::open(&root, false).unwrap();
        log.emit("submit", &[("run", s("r0001-a")), ("priority", n(3))])
            .unwrap();
        log.emit("slice", &[("run", s("r0001-a")), ("batches", n(8))])
            .unwrap();
        let ev = read_events(&root).unwrap();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].get("event").and_then(Json::as_str),
                   Some("submit"));
        assert_eq!(ev[1].get("batches").and_then(Json::as_f64),
                   Some(8.0));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn seq_continues_across_reopen() {
        let root = tmp("seq");
        let mut log = EventLog::open(&root, false).unwrap();
        log.emit("daemon-start", &[]).unwrap();
        drop(log);
        let mut log = EventLog::open(&root, false).unwrap();
        log.emit("daemon-start", &[]).unwrap();
        let ev = read_events(&root).unwrap();
        let seqs: Vec<f64> = ev
            .iter()
            .map(|e| e.get("seq").and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(seqs, vec![0.0, 1.0]);
        let _ = std::fs::remove_dir_all(&root);
    }
}
