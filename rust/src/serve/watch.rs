//! Submission intake for the experiment service.
//!
//! Both intake modes — the watched inbox directory and the stdin
//! line mode — funnel through one strict parser: a submission is a
//! spec JSON object (the exact [`crate::session::Spec`] schema) plus
//! at most one extra top-level key, `"priority"` (an integer; higher
//! runs first; default 0).  The priority key is stripped *before*
//! the spec parse, so the spec schema itself stays closed — an
//! unknown key is still a typed rejection, never a silent no-op.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::jsonx::Json;
use crate::session::{Spec, SpecError};

/// Largest integer JSON numbers represent exactly (2^53); priorities
/// beyond it would not round-trip through the state files.
const MAX_EXACT_PRIORITY: f64 = 9_007_199_254_740_992.0;

/// Why a submission was rejected.  Rejections move the file to
/// `failed/` with a `<name>.reason` sidecar and emit a `reject`
/// event — they never crash the daemon.  The Display strings are
/// part of the service contract and pinned by `tests/serve.rs`.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// The file was not JSON at all.
    NotJson(String),
    /// The top-level value was JSON, but not an object.
    NotAnObject,
    /// A `"priority"` that is not an exactly-representable integer.
    BadPriority,
    /// The remaining object failed the strict spec parse.
    Spec(SpecError),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        match self {
            SubmitError::NotJson(msg) => {
                write!(f, "submission is not valid JSON: {msg}")
            }
            SubmitError::NotAnObject => {
                write!(f, "submission must be a JSON object (a spec, \
                           plus an optional top-level \"priority\")")
            }
            SubmitError::BadPriority => {
                write!(f, "priority wants an integer with magnitude \
                           at most 2^53")
            }
            SubmitError::Spec(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Parse one submission into its spec and priority.
pub fn parse_submission(text: &str)
                        -> Result<(Spec, i64), SubmitError> {
    let j = Json::parse(text)
        .map_err(|e| SubmitError::NotJson(format!("{e:#}")))?;
    let Json::Obj(mut m) = j else {
        return Err(SubmitError::NotAnObject);
    };
    let priority = match m.remove("priority") {
        None => 0,
        Some(Json::Num(p))
            if p.fract() == 0.0 && p.abs() <= MAX_EXACT_PRIORITY =>
        {
            p as i64
        }
        Some(_) => return Err(SubmitError::BadPriority),
    };
    let spec =
        Spec::from_json(&Json::Obj(m)).map_err(SubmitError::Spec)?;
    Ok((spec, priority))
}

/// Pending submission files in `dir` (`*.json`, sorted by name for a
/// deterministic admission order).  A missing directory means
/// nothing is pending — the watcher must tolerate the inbox being
/// created late or removed out from under it.
pub fn list_submissions(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(out),
    };
    for entry in rd {
        let path = entry
            .with_context(|| format!("reading {}", dir.display()))?
            .path();
        if path.extension().and_then(|e| e.to_str()) == Some("json")
            && path.is_file()
        {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

/// A submission-derived directory-name stem: the file stem with
/// anything outside `[A-Za-z0-9_-]` folded to `-`, capped at 40
/// chars, never empty.
pub fn sanitize_stem(name: &str) -> String {
    let stem = name.strip_suffix(".json").unwrap_or(name);
    let mut s: String = stem
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '-'
            }
        })
        .collect();
    s.truncate(40);
    if s.is_empty() {
        s.push_str("run");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY_SPEC: &str = r#"{
      "version": 1,
      "net": {"preset": "1x"},
      "hyper": {"batch": 4},
      "run": {"epochs": 2, "images": 12}
    }"#;

    fn with_priority(p: &str) -> String {
        TINY_SPEC.replacen('{', &format!("{{\"priority\": {p},"), 1)
    }

    #[test]
    fn priority_is_stripped_before_the_strict_spec_parse() {
        let (spec, pri) =
            parse_submission(&with_priority("5")).unwrap();
        assert_eq!(pri, 5);
        assert_eq!(spec.batch, 4);
        assert_eq!(spec.epochs, 2);
        // no priority key -> default 0
        let (_, pri) = parse_submission(TINY_SPEC).unwrap();
        assert_eq!(pri, 0);
        // negative priorities are allowed (background work)
        let (_, pri) =
            parse_submission(&with_priority("-3")).unwrap();
        assert_eq!(pri, -3);
    }

    #[test]
    fn rejections_are_typed_with_pinned_messages() {
        let e = parse_submission("{nope").unwrap_err();
        assert!(matches!(e, SubmitError::NotJson(_)));
        assert!(e.to_string().starts_with(
            "submission is not valid JSON:"), "{e}");

        let e = parse_submission("[1,2]").unwrap_err();
        assert_eq!(e, SubmitError::NotAnObject);
        assert_eq!(e.to_string(),
                   "submission must be a JSON object (a spec, plus \
                    an optional top-level \"priority\")");

        let e =
            parse_submission(&with_priority("1.5")).unwrap_err();
        assert_eq!(e, SubmitError::BadPriority);
        assert_eq!(e.to_string(),
                   "priority wants an integer with magnitude at \
                    most 2^53");

        // an unknown spec key passes through as the spec's own
        // typed error
        let bad = TINY_SPEC.replacen("\"run\"", "\"runn\"", 1);
        let e = parse_submission(&bad).unwrap_err();
        let SubmitError::Spec(se) = &e else {
            panic!("want Spec(..), got {e:?}");
        };
        assert_eq!(se.to_string(),
                   "unknown field `runn` in the spec");
    }

    #[test]
    fn stems_sanitize_and_never_empty() {
        assert_eq!(sanitize_stem("a.json"), "a");
        assert_eq!(sanitize_stem("my run (v2).json"), "my-run--v2-");
        assert_eq!(sanitize_stem(".json"), "run");
        assert_eq!(sanitize_stem("x".repeat(80).as_str()).len(), 40);
    }
}
