//! The time-slicing scheduler: the daemon's control loop.
//!
//! One `tick` = admit pending submissions, pick the next runnable
//! run, and train it for one slice (`max_batches` as the preemption
//! point, via [`Session::begin_slice`]) before writing its state back
//! and returning.  Scheduling policy, in order:
//!
//! 1. the *admitted set* is the top `max_active` runnable runs by
//!    (priority desc, admission order) — at most N sessions share
//!    the machine, everyone else waits in line;
//! 2. within the admitted set the next slice goes to the
//!    least-served run (fewest recorded slices), ties to the
//!    earliest submission — equal priorities interleave and neither
//!    starves;
//! 3. a higher-priority submission enters the admitted set on the
//!    very next tick and, sorting first, wins the next slice — it
//!    preempts at the slice boundary, never mid-batch.
//!
//! Crash safety: a run is marked `running` (durably) before its
//! slice and written back after, so a `kill -9` mid-slice is visible
//! at recovery; the slice's own checkpoints are atomic, and
//! [`Session::begin_slice`] pins the checkpoint cadence to the slice
//! length, so the recovered run resumes from its newest checkpoint
//! bit-identically — at worst replaying the killed slice's batches.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, TryRecvError};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::ckpt::{self, Cursor};
use crate::session::{Session, Spec};

use super::event::{n, s, EventLog};
use super::queue::{RunPhase, RunState, ServeRoot, CKPT_SUBDIR};
use super::watch::{self, SubmitError};

/// Daemon knobs (CLI flags map 1:1; see `stratus serve` usage).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// The serve root: queue state, checkpoints, event log.
    pub root: PathBuf,
    /// Watched submission directory (default `<root>/inbox`).
    pub watch: Option<PathBuf>,
    /// Batches per slice — the preemption granularity.
    pub slice_batches: u64,
    /// How many runs time-share the machine at once.
    pub max_active: usize,
    /// Worker-thread budget: each slice trains with
    /// `min(spec.workers, worker_budget)` engine threads (worker
    /// count is excluded from the fingerprint, so capping is always
    /// bit-identical).
    pub worker_budget: usize,
    /// Idle sleep between polls, in milliseconds.
    pub poll_ms: u64,
    /// Exit once the queue and inbox are empty (and stdin, when
    /// enabled, has reached EOF) instead of waiting for more work.
    pub drain: bool,
    /// Also accept one submission per stdin line.
    pub stdin: bool,
    /// Echo every event line to stdout.
    pub echo: bool,
}

impl ServeConfig {
    /// Defaults used by the tests: quiet, no stdin, no drain.
    pub fn new(root: impl Into<PathBuf>) -> ServeConfig {
        ServeConfig {
            root: root.into(),
            watch: None,
            slice_batches: 8,
            max_active: 2,
            worker_budget: 4,
            poll_ms: 200,
            drain: false,
            stdin: false,
            echo: false,
        }
    }
}

/// What one tick did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tick {
    /// Nothing runnable (queue empty or everything done/failed).
    Idle,
    /// Ran one slice of `id`; `done` when the run completed.
    Sliced { id: String, done: bool },
    /// The run's slice errored; the run is now `failed`.
    Failed { id: String },
    /// Chaos hook only: the slice was abandoned mid-flight as a
    /// `kill -9` would — nothing was recorded, and the run's durable
    /// state still says `running`.  The scheduler must be dropped
    /// and re-opened (recovery) before that run can make progress.
    Killed { id: String },
}

struct StdinFeed {
    rx: Receiver<String>,
    done: bool,
    count: u64,
}

/// The daemon state: a durable queue mirror plus the event stream.
pub struct Scheduler {
    cfg: ServeConfig,
    root: ServeRoot,
    watch_dir: PathBuf,
    log: EventLog,
    runs: Vec<RunState>,
    next_seq: u64,
    stdin: Option<StdinFeed>,
}

impl Scheduler {
    /// Open (or recover) the serve root.  Runs found `running` —
    /// i.e. the previous daemon died mid-slice — are requeued; they
    /// resume from their newest checkpoint.
    pub fn open(cfg: ServeConfig) -> Result<Scheduler> {
        if cfg.slice_batches == 0 {
            bail!("slice-batches must be at least 1");
        }
        if cfg.max_active == 0 {
            bail!("active must be at least 1");
        }
        if cfg.worker_budget == 0 {
            bail!("workers-budget must be at least 1");
        }
        let root = ServeRoot::open(&cfg.root)?;
        let watch_dir =
            cfg.watch.clone().unwrap_or_else(|| root.inbox_dir());
        let mut log = EventLog::open(&cfg.root, cfg.echo)?;
        let mut runs = root.scan()?;
        let mut recovered = 0u64;
        for st in &mut runs {
            if st.phase != RunPhase::Running {
                continue;
            }
            st.phase = RunPhase::Queued;
            // refresh the display cursor from the checkpoint: the
            // killed slice may have saved epoch-boundary checkpoints
            // past the last recorded state
            let ck = root.ckpt_path(&st.id);
            if let Ok(cur) = ckpt::peek_cursor(&ck) {
                st.epoch = cur.epoch;
                st.batch = cur.batch;
            }
            st.save_atomic(&root.run_dir(&st.id))?;
            log.emit("recover",
                     &[("run", s(st.id.as_str())),
                       ("epoch", n(st.epoch)),
                       ("batch", n(st.batch))])?;
            recovered += 1;
        }
        let next_seq =
            runs.iter().map(|r| r.seq).max().map_or(1, |m| m + 1);
        log.emit("daemon-start",
                 &[("runs", n(runs.len() as u64)),
                   ("recovered", n(recovered)),
                   ("slice_batches", n(cfg.slice_batches)),
                   ("max_active", n(cfg.max_active as u64))])?;
        let stdin = if cfg.stdin {
            Some(spawn_stdin_feed())
        } else {
            None
        };
        Ok(Scheduler {
            cfg,
            root,
            watch_dir,
            log,
            runs,
            next_seq,
            stdin,
        })
    }

    /// The serve root this scheduler drives.
    pub fn root(&self) -> &Path {
        self.root.path()
    }

    /// In-memory queue snapshot (sorted by admission order).
    pub fn runs(&self) -> &[RunState] {
        &self.runs
    }

    /// Admit everything pending: inbox files, then stdin lines.
    /// Malformed submissions are moved to `failed/` with a reason
    /// file and a `reject` event — they never take the daemon down.
    pub fn poll_submissions(&mut self) -> Result<usize> {
        let mut admitted = 0;
        for path in watch::list_submissions(&self.watch_dir)? {
            if self.ingest_file(&path)? {
                admitted += 1;
            }
        }
        while let Some(line) = self.try_stdin_line() {
            if line.trim().is_empty() {
                continue;
            }
            let feed = self.stdin.as_mut().expect("line implies feed");
            feed.count += 1;
            let name = format!("stdin-{}.json", feed.count);
            if self.ingest_text(&name, &line)? {
                admitted += 1;
            }
        }
        Ok(admitted)
    }

    /// Run the daemon until the queue drains (with `cfg.drain`) or
    /// forever (a service: killing it is the shutdown path, and
    /// recovery on the next open is the restart path).
    pub fn run_loop(&mut self) -> Result<()> {
        loop {
            if self.tick()? == Tick::Idle {
                if self.cfg.drain && self.drained()? {
                    break;
                }
                std::thread::sleep(Duration::from_millis(
                    self.cfg.poll_ms.max(1),
                ));
            }
        }
        self.log.emit("daemon-drain",
                      &[("runs", n(self.runs.len() as u64))])?;
        Ok(())
    }

    /// One scheduling step (see module docs for the policy).
    pub fn tick(&mut self) -> Result<Tick> {
        self.tick_with_kill(None)
    }

    /// `tick`, with the chaos-test kill hook: `Some(k)` with `k`
    /// below the slice length abandons the slice after `k` batches
    /// exactly as a `kill -9` would — the durable state keeps saying
    /// `running`, nothing is recorded, and only the checkpoints the
    /// cadence already saved exist.  See [`Tick::Killed`] for the
    /// mandatory drop-and-reopen that follows.
    pub fn tick_with_kill(&mut self, kill_after: Option<u64>)
                          -> Result<Tick> {
        self.poll_submissions()?;
        let Some(i) = self.pick_next() else {
            return Ok(Tick::Idle);
        };
        let id = self.runs[i].id.clone();
        let dir = self.root.run_dir(&id);
        let first = self.runs[i].slices == 0
            && !self.root.ckpt_path(&id).exists();
        // durably mark the slice in flight *before* any numerics: a
        // daemon killed from here on is detectable at recovery
        self.runs[i].phase = RunPhase::Running;
        self.runs[i].save_atomic(&dir)?;
        if first {
            self.log.emit("start",
                          &[("run", s(id.as_str())),
                            ("epochs", n(self.runs[i].epochs))])?;
        }
        let killed =
            kill_after.is_some_and(|k| k < self.cfg.slice_batches);
        match self.run_slice(&id, kill_after) {
            Ok(_) if killed => Ok(Tick::Killed { id }),
            Ok((start, end, batch)) => {
                let executed = batches_between(start, end, batch);
                let done = end.epoch >= self.runs[i].epochs;
                let st = &mut self.runs[i];
                st.slices += 1;
                st.batches += executed;
                st.epoch = end.epoch;
                st.batch = end.batch;
                st.phase = if done {
                    RunPhase::Done
                } else {
                    RunPhase::Queued
                };
                let (slices, batches) = (st.slices, st.batches);
                st.save_atomic(&dir)?;
                self.log.emit("slice",
                              &[("run", s(id.as_str())),
                                ("slice", n(slices)),
                                ("batches", n(executed)),
                                ("epoch", n(end.epoch)),
                                ("batch", n(end.batch))])?;
                self.log.emit(
                    "checkpoint",
                    &[("run", s(id.as_str())),
                      ("epoch", n(end.epoch)),
                      ("batch", n(end.batch)),
                      ("path",
                       s(self.root
                           .ckpt_path(&id)
                           .display()
                           .to_string()))],
                )?;
                if done {
                    self.log.emit("complete",
                                  &[("run", s(id.as_str())),
                                    ("slices", n(slices)),
                                    ("batches", n(batches))])?;
                }
                Ok(Tick::Sliced { id, done })
            }
            Err(e) => {
                let reason = format!("{e:#}");
                let st = &mut self.runs[i];
                st.phase = RunPhase::Failed;
                st.error = Some(reason.clone());
                st.save_atomic(&dir)?;
                self.log.emit("fail",
                              &[("run", s(id.as_str())),
                                ("reason", s(reason))])?;
                Ok(Tick::Failed { id })
            }
        }
    }

    /// True when nothing can ever become runnable without outside
    /// input: no queued runs, an empty inbox, and (in stdin mode)
    /// EOF on stdin.
    pub fn drained(&self) -> Result<bool> {
        let runnable = self.runs.iter().any(|r| {
            matches!(r.phase, RunPhase::Queued | RunPhase::Running)
        });
        let pending =
            !watch::list_submissions(&self.watch_dir)?.is_empty();
        let stdin_open =
            self.stdin.as_ref().is_some_and(|f| !f.done);
        Ok(!runnable && !pending && !stdin_open)
    }

    // ---------------- internals ----------------

    fn pick_next(&self) -> Option<usize> {
        let mut runnable: Vec<usize> = (0..self.runs.len())
            .filter(|&i| self.runs[i].phase == RunPhase::Queued)
            .collect();
        // the admitted set: top max_active by (priority, seniority)
        runnable.sort_by_key(|&i| {
            (std::cmp::Reverse(self.runs[i].priority),
             self.runs[i].seq)
        });
        runnable.truncate(self.cfg.max_active);
        // within it: highest priority, then least served, then oldest
        runnable.into_iter().min_by_key(|&i| {
            let r = &self.runs[i];
            (std::cmp::Reverse(r.priority), r.slices, r.seq)
        })
    }

    /// Train `id` for one slice; returns (start, end, batch size).
    fn run_slice(&self, id: &str, kill_after: Option<u64>)
                 -> Result<(Cursor, Cursor, usize)> {
        let stored = Spec::load(&self.root.spec_path(id))?;
        // worker_budget >= 1 is enforced at open; spec workers >= 1
        // by build validation
        let workers = stored.workers.clamp(1, self.cfg.worker_budget);
        let spec = stored
            .to_builder()
            .workers(workers)
            .build()
            .context("re-validating the stored run spec")?;
        let batch = spec.batch;
        let epochs = spec.epochs;
        let resume = self.root.ckpt_path(id).exists();
        let session = Session::new(spec)?;
        let mut run =
            session.begin_slice(resume, self.cfg.slice_batches)?;
        if let Some(k) = kill_after {
            if k < self.cfg.slice_batches {
                run = run.cap_batches(k);
            }
        }
        let out = run.execute(|_, _, _| Ok(()))?;
        debug_assert!(out.end.epoch <= epochs);
        Ok((out.start, out.end, batch))
    }

    fn ingest_file(&mut self, path: &Path) -> Result<bool> {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "submission.json".to_string());
        // crash window between run-dir creation and inbox unlink:
        // the run already exists — drop the duplicate, don't retrain
        if self.runs.iter().any(|r| r.source == name) {
            fs::remove_file(path).with_context(|| {
                format!("removing {}", path.display())
            })?;
            self.log.emit("submit-dup",
                          &[("source", s(name))])?;
            return Ok(false);
        }
        let text = fs::read_to_string(path).with_context(|| {
            format!("reading {}", path.display())
        })?;
        match watch::parse_submission(&text) {
            Ok((spec, priority)) => {
                let id = self.admit(&name, &spec, priority)?;
                fs::remove_file(path).with_context(|| {
                    format!("removing {}", path.display())
                })?;
                self.emit_submit(&id, &name, priority)?;
                Ok(true)
            }
            Err(e) => {
                let dst = self.root.failed_dir().join(&name);
                if fs::rename(path, &dst).is_err() {
                    // the watch dir may sit on another filesystem
                    fs::copy(path, &dst).with_context(|| {
                        format!("copying {} -> {}", path.display(),
                                dst.display())
                    })?;
                    fs::remove_file(path)?;
                }
                self.write_reason(&name, &e)?;
                Ok(false)
            }
        }
    }

    fn ingest_text(&mut self, name: &str, text: &str)
                   -> Result<bool> {
        match watch::parse_submission(text) {
            Ok((spec, priority)) => {
                let id = self.admit(name, &spec, priority)?;
                self.emit_submit(&id, name, priority)?;
                Ok(true)
            }
            Err(e) => {
                fs::write(self.root.failed_dir().join(name), text)
                    .context("preserving the rejected submission")?;
                self.write_reason(name, &e)?;
                Ok(false)
            }
        }
    }

    /// Create the run directory: normalized spec (checkpointing
    /// redirected into the run dir, cadence pinned to the slice,
    /// resume normalized off — the scheduler decides resumption per
    /// slice), then the durable state record.
    fn admit(&mut self, source: &str, spec: &Spec, priority: i64)
             -> Result<String> {
        let seq = self.next_seq;
        self.next_seq += 1;
        let id =
            format!("r{seq:04}-{}", watch::sanitize_stem(source));
        let dir = self.root.run_dir(&id);
        fs::create_dir_all(dir.join(CKPT_SUBDIR)).with_context(
            || format!("creating {}", dir.display()),
        )?;
        let normalized = spec
            .to_builder()
            .checkpoint_dir(self.root.ckpt_dir(&id))
            .checkpoint_every(self.cfg.slice_batches)
            .resume(false)
            .build()
            .context("normalizing the submitted spec")?;
        normalized.save(&self.root.spec_path(&id))?;
        let st = RunState {
            id: id.clone(),
            seq,
            priority,
            source: source.to_string(),
            phase: RunPhase::Queued,
            slices: 0,
            batches: 0,
            epoch: 0,
            batch: 0,
            epochs: normalized.epochs,
            error: None,
        };
        st.save_atomic(&dir)?;
        self.runs.push(st);
        Ok(id)
    }

    fn emit_submit(&mut self, id: &str, source: &str, priority: i64)
                   -> Result<()> {
        self.log.emit("submit",
                      &[("run", s(id)),
                        ("source", s(source)),
                        ("priority",
                         crate::jsonx::Json::Num(priority as f64))])
    }

    fn write_reason(&mut self, name: &str, e: &SubmitError)
                    -> Result<()> {
        let reason_path =
            self.root.failed_dir().join(format!("{name}.reason"));
        fs::write(&reason_path, format!("{e}\n")).with_context(
            || format!("writing {}", reason_path.display()),
        )?;
        self.log.emit("reject",
                      &[("source", s(name)),
                        ("reason", s(e.to_string()))])?;
        Ok(())
    }

    fn try_stdin_line(&mut self) -> Option<String> {
        let feed = self.stdin.as_mut()?;
        if feed.done {
            return None;
        }
        match feed.rx.try_recv() {
            Ok(line) => Some(line),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                feed.done = true;
                None
            }
        }
    }
}

/// Batches between two cursors of the same run (`end` is never
/// before `start`; an epoch is `ceil(images / batch)` batches).
fn batches_between(start: Cursor, end: Cursor, batch: usize) -> u64 {
    let bpe = start.images.div_ceil((batch as u64).max(1));
    (end.epoch * bpe + end.batch) - (start.epoch * bpe + start.batch)
}

fn spawn_stdin_feed() -> StdinFeed {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        use std::io::BufRead as _;
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
        // dropping tx disconnects the channel: that is EOF
    });
    StdinFeed { rx, done: false, count: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_between_counts_across_epoch_boundaries() {
        let c = |epoch, batch| Cursor {
            epoch,
            batch,
            seed: 7,
            images: 12,
        };
        // 12 images at batch 4 -> 3 batches/epoch
        assert_eq!(batches_between(c(0, 0), c(0, 2), 4), 2);
        assert_eq!(batches_between(c(0, 2), c(1, 0), 4), 1);
        assert_eq!(batches_between(c(0, 2), c(2, 0), 4), 4);
        assert_eq!(batches_between(c(1, 1), c(1, 1), 4), 0);
    }
}
