//! Minimal JSON parser — enough for `artifacts/manifest.json` and config
//! files.  (The offline build environment only vendors the `xla` crate's
//! dependency closure, so serde is not available; see DESIGN.md
//! §Substitutions.)  Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]` (for shape lists in the manifest).
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    /// Render with two-space indentation and strict JSON string
    /// escaping (control characters become `\uXXXX`), so the output is
    /// always re-parseable — unlike [`fmt::Display`], which reuses
    /// Rust's debug escapes.  Used for `--dump-spec` files meant to be
    /// read back (and edited) by humans.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    /// Render on a single line with the same strict escaping as
    /// [`Json::pretty`] — one value per line, always re-parseable.
    /// This is the JSON-lines form the serve event stream appends to
    /// `events.jsonl` (unlike [`fmt::Display`], which reuses Rust's
    /// debug escapes and is for human eyes only).
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.compact_into(&mut out);
        out
    }

    fn compact_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else {
                "false"
            }),
            // same inf/NaN fallback as pretty_into
            Json::Num(n) if !n.is_finite() => out.push_str("null"),
            Json::Num(n) => out.push_str(&n.to_string()),
            Json::Str(s) => escape_json(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.compact_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_json(k, out);
                    out.push(':');
                    x.compact_into(out);
                }
                out.push('}');
            }
        }
    }

    fn pretty_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else {
                "false"
            }),
            // JSON has no inf/NaN — fall back to null rather than
            // emitting an unparseable token (callers that care
            // validate finiteness before serializing, e.g.
            // session::validate)
            Json::Num(n) if !n.is_finite() => out.push_str("null"),
            Json::Num(n) => out.push_str(&n.to_string()),
            Json::Str(s) => escape_json(s, out),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    x.pretty_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    escape_json(k, out);
                    out.push_str(": ");
                    x.pretty_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, levels: usize) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

/// Escape `s` as a JSON string literal into `out`.
fn escape_json(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{:?}", s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{x}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", c as char, self.pos)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| {
                        anyhow::anyhow!("truncated escape")
                    })?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos..self.pos + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(),
                   Json::Str("hi\n".into()));
    }

    #[test]
    fn nested_structure() {
        let j = Json::parse(r#"{"ops":{"a":{"inputs":[[3,32,32],[16]]}},"n":2}"#)
            .unwrap();
        let shape = j
            .get("ops")
            .and_then(|o| o.get("a"))
            .and_then(|a| a.get("inputs"))
            .and_then(|i| i.idx(0))
            .and_then(|s| s.as_shape())
            .unwrap();
        assert_eq!(shape, vec![3, 32, 32]);
        assert_eq!(j.get("n").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn whitespace_and_empties() {
        let j = Json::parse(" { \"a\" : [ ] , \"b\" : { } } ").unwrap();
        assert_eq!(j.get("a").unwrap(), &Json::Arr(vec![]));
        assert!(j.get("b").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(),
                   Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn pretty_output_reparses_identically() {
        let j = Json::parse(
            r#"{"a":[1,2,{"b":"line\nbreak","q":"say \"hi\""}],"c":{},"d":[],"e":null,"f":true}"#,
        )
        .unwrap();
        let text = j.pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // readable: indented, one key per line
        assert!(text.contains("\n  \"a\": ["));
        assert!(text.ends_with("}\n"));
    }

    #[test]
    fn compact_is_one_strict_reparseable_line() {
        let j = Json::parse(
            r#"{"event":"slice","run":"r0001-a","n":2,"note":"a\nb"}"#,
        )
        .unwrap();
        let line = j.compact();
        assert!(!line.contains('\n'), "{line}");
        assert_eq!(Json::parse(&line).unwrap(), j);
        assert_eq!(line,
                   r#"{"event":"slice","n":2,"note":"a\nb","run":"r0001-a"}"#);
    }

    #[test]
    fn pretty_escapes_control_characters_strictly() {
        let j = Json::Str("ctl\u{1}tab\there".into());
        let text = j.pretty();
        assert!(text.contains("\\u0001"), "{text}");
        assert!(text.contains("\\t"), "{text}");
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("ops").is_some());
            assert!(j.get("qformat").is_some());
        }
    }
}
