//! The layer-ops registry: one descriptor per layer kind that owns that
//! kind's semantics end to end — naming, parameter/statistic inventory,
//! output geometry, MAC counts for every training phase, on-chip buffer
//! requirements, RTL module selection, control-ROM words, schedule-step
//! emission, and simulated cycle costs.
//!
//! Before this module existed, those facts were duplicated as
//! `match Layer::` arms across `config`, `compiler/{module_library,
//! schedule, codegen, adaptive}`, `sim`, `hw/{bram, mac_array}` and the
//! coordinator; adding a layer kind meant touching every one of them in
//! sync.  Now `compiler/`, `sim/` and `hw/` consult [`for_layer`] — the
//! single dispatch point — and adding a layer kind is one descriptor in
//! this file plus its golden-model numerics (see [`BnOps`], the first
//! layer added this way).  This is the modular per-layer-descriptor
//! architecture the accelerator-compiler literature uses to scale layer
//! coverage (TinyCNN, arXiv:1911.06777; Chung & Abdelrahman,
//! arXiv:2203.04015).
//!
//! The descriptors are stateless: every method takes the concrete
//! [`Layer`] value and reads its dimensions.  Schedule emission receives
//! a [`StepCtx`] carrying what the walk knows (the consumed geometry,
//! the layer below, first-layer-ness), and every emitted [`Step`] records
//! its output geometry — downstream consumers (e.g. the per-op runtime
//! walk) read `step.out_shape` instead of re-deriving geometry from the
//! layer list.

use crate::compiler::codegen::ControlWord;
use crate::compiler::module_library::Module;
use crate::compiler::schedule::{OpKind, Step};
use crate::config::{DesignVars, Layer};
use crate::fixed::{SHIFT_CONV_BP, SHIFT_CONV_FP, SHIFT_WU_STORE};
use crate::hw::bram::{BufferGroup, BufferSpec};
use crate::hw::mac_array::{self, LogicCost, Phase};
use crate::nn::bn::FQ_SHIFT;

/// Bytes per 16-bit data word.
pub const W16: u64 = 2;
/// Bytes per 32-bit gradient/statistic accumulator word.
pub const W32: u64 = 4;

/// DMA tile count for a (C, H, W) tensor moved `tile_rows` rows at a
/// time, `pof` maps per burst.
pub fn act_tiles(dv: &DesignVars, c: usize, h: usize) -> u64 {
    (c.div_ceil(dv.pof) * h.div_ceil(dv.tile_rows)) as u64
}

/// A (C, H, W) feature-map geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geom {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Geom {
    pub fn elems(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn shape(&self) -> Vec<usize> {
        vec![self.c, self.h, self.w]
    }
}

/// What the schedule walk knows when it asks a descriptor to emit steps.
pub struct StepCtx<'a> {
    /// Artifact-name scale tag ("1x"/"2x"/"4x").
    pub tag: &'a str,
    /// Geometry this layer consumes (the layer below's output geometry,
    /// or the network input for the first layer).
    pub in_geom: Geom,
    /// True for the first layer of the network (BP stops here).
    pub is_first: bool,
    /// The layer below in FP order (`None` for the first layer).
    pub below: Option<&'a Layer>,
}

// ------------------------------------------------ range contracts

/// Largest |x · w| one 16-bit MAC tap can produce: the asymmetric i16
/// range pairs 32768 (`i16::MIN` magnitude) with 32767.
pub const TAP_MAX: i64 = 32768 * 32767;
/// Largest |value| a `sat16`-bounded word can carry (`|i16::MIN|`).
pub const SAT_MAX: i64 = 32768;
/// SGD clamps bias parameters (held at FA+FW) to ±2^28
/// (`nn::sgd::ParamState::apply`), so a bias seeding a MAC accumulator
/// is bounded by this, not by the i32 range.
pub const BIAS_MAX: i64 = 1 << 28;

/// The worst-case range contract of one i32 accumulator a layer's
/// kernels drive — the per-op input to the static fixed-point range
/// analyzer (`crate::analysis`).  Magnitudes are exact worst cases
/// under fully ±i16-saturated inputs, in i64 so the contract itself
/// cannot overflow while describing an overflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccContract {
    /// Short accumulator tag (`fp-mac`, `wgrad-sum`, `moment-sum`, ...).
    pub acc: &'static str,
    /// Training phase whose pass drives this accumulator.
    pub phase: Phase,
    /// Worst |value| the accumulator reaches while processing ONE
    /// image, before any store shift.
    pub per_image_raw: i64,
    /// Round-half-up right shift applied when the per-image result is
    /// handed on (`SHIFT_CONV_FP/BP` requant, `SHIFT_WU_STORE`,
    /// `FQ_SHIFT`); 0 when stored unshifted.
    pub store_shift: u32,
    /// True: the shifted per-image results accumulate across the whole
    /// batch in one wrapping i32 (DRAM gradient / statistic
    /// accumulators).  False: the accumulator is reset per image.
    pub per_batch: bool,
    /// True: a wrap silently corrupts semantics (BN statistic sums feed
    /// `inv_std`/EMA), so the analyzer must prove exactness and the
    /// spec gate refuses batch sizes that can wrap it.  False: wrapping
    /// is the documented deterministic i32 contract shared with the
    /// XLA-lowered kernels (reported, never refused).
    pub must_stay_exact: bool,
}

impl AccContract {
    /// Worst |value| one image contributes to a batch accumulator,
    /// after the store shift.  An i32 chain can never hand more than
    /// `2^31 >> shift` to the store, whatever the raw chain bound says
    /// — the cap models the wrap.
    pub fn per_image_stored(&self) -> i64 {
        let shifted = if self.store_shift == 0 {
            self.per_image_raw
        } else {
            (self.per_image_raw + (1i64 << (self.store_shift - 1)))
                >> self.store_shift
        };
        shifted.min((1i64 << 31) >> self.store_shift)
    }
}

/// Everything one layer kind knows about itself.  Default methods cover
/// the common cases (no parameters, no statistics, no extra buffers);
/// each descriptor overrides what applies.
pub trait LayerOps: Sync {
    /// Kind tag ("conv" / "pool" / "fc" / "bn") — also the control-ROM
    /// kind string.
    fn kind(&self) -> &'static str;

    /// Output feature-map geometry.
    fn out_geom(&self, l: &Layer) -> Geom;

    /// Shape of the weight tensor (`None` for parameterless layers).
    fn weight_shape(&self, l: &Layer) -> Option<Vec<usize>>;

    fn weight_elems(&self, l: &Layer) -> usize {
        self.weight_shape(l).map_or(0, |s| s.iter().product())
    }

    fn bias_elems(&self, l: &Layer) -> usize;

    /// MAC count of the FP pass.
    fn macs_fp(&self, l: &Layer) -> u64;

    /// MAC count of the BP pass (defaults to the FP volume — the if/of
    /// interchange preserves the loop product).
    fn macs_bp(&self, l: &Layer) -> u64 {
        self.macs_fp(l)
    }

    /// MAC count of the weight-gradient pass.
    fn macs_wu(&self, l: &Layer) -> u64;

    /// Whether the layer fuses a ReLU on its output (drives the
    /// activation-gradient mask both in the golden model and in the
    /// schedule's scaling-unit steps).
    fn fused_relu(&self, l: &Layer) -> bool {
        let _ = l;
        false
    }

    /// Trainable parameter names in canonical order (`w_*` then `b_*`).
    fn param_names(&self, l: &Layer) -> Vec<String> {
        if self.weight_elems(l) > 0 {
            vec![format!("w_{}", l.name()), format!("b_{}", l.name())]
        } else {
            Vec::new()
        }
    }

    /// Per-batch statistic accumulators `(name, shape)` this layer
    /// needs (merged across shards exactly like gradients; empty for
    /// layers without batch statistics).  **Order contract:** when
    /// non-empty, exactly `[moment-sum, square-sum]` — the trainer's
    /// batch-end refresh binds them positionally.
    fn stat_tensors(&self, l: &Layer) -> Vec<(String, Vec<usize>)> {
        let _ = l;
        Vec::new()
    }

    /// Persistent (non-SGD) state tensors `(name, shape)` this layer
    /// keeps in the parameter set — e.g. BN running statistics.  They
    /// ride in checkpoints alongside the parameters.  **Order
    /// contract:** when non-empty, exactly `[running-mean,
    /// running-variance]`, paired with [`LayerOps::stat_tensors`].
    fn state_tensors(&self, l: &Layer) -> Vec<(String, Vec<usize>)> {
        let _ = l;
        Vec::new()
    }

    /// RTL library modules this layer requires beyond the base set.
    fn modules(&self, l: &Layer) -> Vec<Module>;

    /// Per-image FP-phase schedule steps.
    fn fp_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                -> Vec<Step>;

    /// Per-image BP/WU-phase schedule steps (reverse walk), in
    /// execution order.
    fn bp_wu_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                   -> Vec<Step>;

    /// Logic cycles the MAC array / function units spend on one
    /// scheduled op of this layer.  The default covers the per-batch
    /// weight update (Pof-wide update datapath); ops a kind does not
    /// emit cost zero.
    fn logic_cycles(&self, dv: &DesignVars, l: &Layer, op: OpKind)
                    -> u64 {
        match op {
            OpKind::WeightUpdate => {
                (self.weight_elems(l) as u64).div_ceil(dv.pof as u64)
            }
            _ => 0,
        }
    }

    /// Logic cost of one whole phase through this layer (`None` when
    /// the phase does not visit it) — the analytic form the mac-array
    /// model exposes.
    fn phase_cost(&self, dv: &DesignVars, l: &Layer, phase: Phase,
                  is_first: bool) -> Option<LogicCost>;

    /// Input-tile row width in words (drives the shared input buffer).
    fn input_row_words(&self, l: &Layer) -> u64;

    /// Output-tile row width in words (drives the shared output buffer).
    fn output_row_words(&self, l: &Layer) -> u64;

    /// Weight-gradient accumulation tile depth in i32 words.
    fn weight_grad_tile_words(&self, l: &Layer, dv: &DesignVars) -> u64;

    /// Layer-private buffers (pool indices, ReLU masks, BN statistic
    /// registers); appended to the buffer plan.
    fn layer_buffers(&self, l: &Layer, dv: &DesignVars,
                     out: &mut Vec<BufferSpec>) {
        let _ = (l, dv, out);
    }

    /// Control-ROM word for the global control logic.
    fn control_word(&self, l: &Layer, dv: &DesignVars) -> ControlWord;

    /// i32 words of host-side kernel workspace this layer needs while
    /// one image passes through it — the zero-padded input plane for
    /// convs (FP pads the input, BP the gradient, WU the input again;
    /// the widest is `max(cin, cout)` padded planes deep).  Sizes the
    /// one-time presizing in
    /// [`Scratch::for_net`](crate::nn::scratch::Scratch::for_net);
    /// layers whose kernels read their inputs in place report 0.
    fn host_scratch_words(&self, l: &Layer) -> usize {
        let _ = l;
        0
    }

    /// Worst-case range contracts for every i32 accumulator this
    /// layer's kernels drive (see [`AccContract`]); the static range
    /// analyzer propagates these through batch size and cluster merge.
    /// Default: none (pool is compare/route only — `sat16` on the
    /// mask multiply, no accumulation).
    fn range_contracts(&self, l: &Layer) -> Vec<AccContract> {
        let _ = l;
        Vec::new()
    }
}

/// The registry dispatch: the one place a layer kind maps to its
/// descriptor.  Everything in `compiler/`, `sim/` and `hw/` reaches
/// layer semantics through this function.
pub fn for_layer(l: &Layer) -> &'static dyn LayerOps {
    match l {
        Layer::Conv { .. } => &ConvOps,
        Layer::Pool { .. } => &PoolOps,
        Layer::Fc { .. } => &FcOps,
        Layer::Bn { .. } => &BnOps,
    }
}

// ---------------------------------------------------------------- conv

pub struct ConvOps;

impl LayerOps for ConvOps {
    fn kind(&self) -> &'static str {
        "conv"
    }

    fn out_geom(&self, l: &Layer) -> Geom {
        let Layer::Conv { cout, h, w, .. } = *l else { unreachable!() };
        Geom { c: cout, h, w }
    }

    fn weight_shape(&self, l: &Layer) -> Option<Vec<usize>> {
        let Layer::Conv { cin, cout, k, .. } = *l else { unreachable!() };
        Some(vec![cout, cin, k, k])
    }

    fn bias_elems(&self, l: &Layer) -> usize {
        let Layer::Conv { cout, .. } = *l else { unreachable!() };
        cout
    }

    fn macs_fp(&self, l: &Layer) -> u64 {
        let Layer::Conv { cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        (cout * h * w * cin * k * k) as u64
    }

    fn macs_wu(&self, l: &Layer) -> u64 {
        let Layer::Conv { cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        // every (of, if) kernel-gradient plane convolves a full
        // gradient map: Nof*Nif*Nk*Nk output taps x Noy*Nox each
        (cout * cin * k * k * h * w) as u64
    }

    fn fused_relu(&self, l: &Layer) -> bool {
        let Layer::Conv { relu, .. } = *l else { unreachable!() };
        relu
    }

    fn host_scratch_words(&self, l: &Layer) -> usize {
        let Layer::Conv { cin, cout, h, w, pad, .. } = *l else {
            unreachable!()
        };
        // FP/WU pad the cin-deep input plane, BP the cout-deep
        // gradient plane — the workspace must hold the wider of the two
        cin.max(cout) * (h + 2 * pad) * (w + 2 * pad)
    }

    fn modules(&self, l: &Layer) -> Vec<Module> {
        if self.fused_relu(l) {
            vec![Module::ReluUnit, Module::ScalingUnit]
        } else {
            Vec::new()
        }
    }

    fn fp_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                -> Vec<Step> {
        let Layer::Conv { ref name, cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        let in_b = (cin * h * w) as u64 * W16;
        let w_b = ((cout * cin * k * k) + cout) as u64 * W16;
        let out_b = (cout * h * w) as u64 * W16;
        // ReLU is affiliated (fused in the artifact); masks stay on
        // chip, so no separate step/traffic.
        vec![Step {
            phase: Phase::Fp,
            layer: name.clone(),
            op: OpKind::ConvFp,
            key: true,
            artifact: Some(format!("conv_fp_{name}_{}", ctx.tag)),
            dram_read_bytes: in_b + w_b,
            dram_write_bytes: out_b,
            tiles: act_tiles(dv, cin, h)
                + act_tiles(dv, cout, h)
                + cout.div_ceil(dv.pof) as u64,
            out_shape: vec![cout, h, w],
        }]
    }

    fn bp_wu_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                   -> Vec<Step> {
        let Layer::Conv { ref name, cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        let mut steps = Vec::new();
        // WU: read input acts + local grads + old accumulated grads;
        // write new accumulated grads (i32 in DRAM)
        let dw_elems = (cout * cin * k * k) as u64;
        steps.push(Step {
            phase: Phase::Wu,
            layer: name.clone(),
            op: OpKind::ConvWu,
            key: true,
            artifact: Some(format!("conv_wu_{name}_{}", ctx.tag)),
            dram_read_bytes: ((cin * h * w) + (cout * h * w)) as u64
                * W16
                + dw_elems * W32,
            dram_write_bytes: dw_elems * W32 + (cout as u64) * W32,
            tiles: act_tiles(dv, cin, h)
                + act_tiles(dv, cout, h)
                + 2 * cout.div_ceil(dv.pof) as u64,
            out_shape: vec![cout, cin, k, k],
        });
        if !ctx.is_first {
            // BP conv through transposable weights
            steps.push(Step {
                phase: Phase::Bp,
                layer: name.clone(),
                op: OpKind::ConvBp,
                key: true,
                artifact: Some(format!("conv_bp_{name}_{}", ctx.tag)),
                dram_read_bytes: ((cout * h * w) + (cout * cin * k * k))
                    as u64
                    * W16,
                dram_write_bytes: (cin * h * w) as u64 * W16,
                tiles: act_tiles(dv, cout, h)
                    + act_tiles(dv, cin, h)
                    + cout.div_ceil(dv.pof) as u64,
                out_shape: vec![cin, h, w],
            });
            // scaling unit when the layer below fuses a ReLU (its
            // binary activation-gradient mask scales the propagated
            // gradient); only conv masks have AOT artifacts
            if let Some(b) = ctx.below {
                let b_ops = for_layer(b);
                if b_ops.fused_relu(b) {
                    let artifact = if b_ops.kind() == "conv" {
                        Some(format!("smask_{}_{}", b.name(), ctx.tag))
                    } else {
                        None // BN masks are golden-backend-only
                    };
                    steps.push(Step {
                        phase: Phase::Bp,
                        layer: name.clone(),
                        op: OpKind::ScaleMask,
                        key: false,
                        artifact,
                        dram_read_bytes: 0,
                        dram_write_bytes: 0,
                        tiles: 0,
                        out_shape: vec![cin, h, w],
                    });
                }
            }
        }
        steps
    }

    fn logic_cycles(&self, dv: &DesignVars, l: &Layer, op: OpKind)
                    -> u64 {
        let Layer::Conv { cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        match op {
            OpKind::ConvFp => {
                mac_array::conv_cycles(dv, cin, cout, h, w, k).cycles
            }
            OpKind::ConvBp => {
                mac_array::conv_cycles(dv, cout, cin, h, w, k).cycles
            }
            OpKind::ConvWu => {
                mac_array::wu_cycles(dv, cin, cout, h, w, k).cycles
            }
            OpKind::WeightUpdate => {
                (self.weight_elems(l) as u64).div_ceil(dv.pof as u64)
            }
            _ => 0,
        }
    }

    fn phase_cost(&self, dv: &DesignVars, l: &Layer, phase: Phase,
                  is_first: bool) -> Option<LogicCost> {
        let Layer::Conv { cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        match phase {
            Phase::Fp => Some(mac_array::conv_cycles(dv, cin, cout, h,
                                                     w, k)),
            Phase::Bp => {
                if is_first {
                    None
                } else {
                    // if/of interchange: same loop volume
                    Some(mac_array::conv_cycles(dv, cout, cin, h, w, k))
                }
            }
            Phase::Wu => Some(mac_array::wu_cycles(dv, cin, cout, h, w,
                                                   k)),
        }
    }

    fn input_row_words(&self, l: &Layer) -> u64 {
        let Layer::Conv { cin, w, .. } = *l else { unreachable!() };
        (cin * (w + 2)) as u64
    }

    fn output_row_words(&self, l: &Layer) -> u64 {
        let Layer::Conv { w, .. } = *l else { unreachable!() };
        w as u64
    }

    fn weight_grad_tile_words(&self, l: &Layer, dv: &DesignVars) -> u64 {
        let Layer::Conv { cin, k, .. } = *l else { unreachable!() };
        (dv.pof * cin * k * k) as u64
    }

    fn layer_buffers(&self, l: &Layer, _dv: &DesignVars,
                     out: &mut Vec<BufferSpec>) {
        let Layer::Conv { ref name, cout, h, w, relu, .. } = *l else {
            unreachable!()
        };
        // per-relu-layer binary activation-gradient buffer
        if relu {
            out.push(BufferSpec {
                name: format!("mask_{name}"),
                group: BufferGroup::ActGradientMask,
                words: (cout * h * w) as u64,
                bits_per_word: 1,
                double: false,
            });
        }
    }

    fn control_word(&self, l: &Layer, dv: &DesignVars) -> ControlWord {
        let Layer::Conv { ref name, cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        ControlWord {
            layer: name.clone(),
            kind: "conv",
            nif: cin,
            nof: cout,
            nox: w,
            noy: h,
            nkx: k,
            tiles_y: h.div_ceil(dv.tile_rows),
            tiles_of: cout.div_ceil(dv.pof),
        }
    }

    fn range_contracts(&self, l: &Layer) -> Vec<AccContract> {
        let Layer::Conv { cin, cout, h, w, k, .. } = *l else {
            unreachable!()
        };
        let hw = (h * w) as i64;
        let taps_fp = (cin * k * k) as i64;
        let taps_bp = (cout * k * k) as i64;
        vec![
            // FP MAC chain: the bias (at FA+FW) seeds the accumulator,
            // then nif·k·k taps; requant+sat16 on store
            AccContract {
                acc: "fp-mac",
                phase: Phase::Fp,
                per_image_raw: BIAS_MAX + taps_fp * TAP_MAX,
                store_shift: SHIFT_CONV_FP,
                per_batch: false,
                must_stay_exact: false,
            },
            // BP through transposed/flipped weights: nof·k·k taps
            AccContract {
                acc: "bp-mac",
                phase: Phase::Bp,
                per_image_raw: taps_bp * TAP_MAX,
                store_shift: SHIFT_CONV_BP,
                per_batch: false,
                must_stay_exact: false,
            },
            // WU per-tap chain: one gradient map (Noy·Nox products)
            // per (of, if, ky, kx) kernel-gradient element
            AccContract {
                acc: "wu-mac",
                phase: Phase::Wu,
                per_image_raw: hw * TAP_MAX,
                store_shift: SHIFT_WU_STORE,
                per_batch: false,
                must_stay_exact: false,
            },
            // the i32 DRAM weight-gradient accumulator: shift_round of
            // each image's wu-mac chain, summed over the whole batch
            AccContract {
                acc: "wgrad-sum",
                phase: Phase::Wu,
                per_image_raw: hw * TAP_MAX,
                store_shift: SHIFT_WU_STORE,
                per_batch: true,
                must_stay_exact: false,
            },
            // bias gradient: plain sum of gradients over Noy·Nox per
            // image, over the batch
            AccContract {
                acc: "bgrad-sum",
                phase: Phase::Wu,
                per_image_raw: hw * SAT_MAX,
                store_shift: 0,
                per_batch: true,
                must_stay_exact: false,
            },
        ]
    }
}

// ---------------------------------------------------------------- pool

pub struct PoolOps;

impl LayerOps for PoolOps {
    fn kind(&self) -> &'static str {
        "pool"
    }

    fn out_geom(&self, l: &Layer) -> Geom {
        let Layer::Pool { c, h, w, k, .. } = *l else { unreachable!() };
        Geom { c, h: h / k, w: w / k }
    }

    fn weight_shape(&self, _l: &Layer) -> Option<Vec<usize>> {
        None
    }

    fn bias_elems(&self, _l: &Layer) -> usize {
        0
    }

    fn macs_fp(&self, _l: &Layer) -> u64 {
        0
    }

    fn macs_wu(&self, _l: &Layer) -> u64 {
        0
    }

    fn modules(&self, _l: &Layer) -> Vec<Module> {
        vec![Module::MaxPoolUnit, Module::UpsampleUnit]
    }

    fn fp_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                -> Vec<Step> {
        let Layer::Pool { ref name, c, h, w, k } = *l else {
            unreachable!()
        };
        let in_b = (c * h * w) as u64 * W16;
        let out_b = (c * (h / k) * (w / k)) as u64 * W16;
        vec![Step {
            phase: Phase::Fp,
            layer: name.clone(),
            op: OpKind::Pool,
            key: true,
            artifact: Some(format!("pool_{name}_{}", ctx.tag)),
            dram_read_bytes: in_b,
            dram_write_bytes: out_b,
            tiles: act_tiles(dv, c, h),
            out_shape: vec![c, h / k, w / k],
        }]
    }

    fn bp_wu_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                   -> Vec<Step> {
        let Layer::Pool { ref name, c, h, w, k } = *l else {
            unreachable!()
        };
        // upsample + scale: reads pooled gradient, writes expanded;
        // indices and masks live on chip (affiliated scaling)
        let in_b = (c * (h / k) * (w / k)) as u64 * W16;
        let out_b = (c * h * w) as u64 * W16;
        vec![Step {
            phase: Phase::Bp,
            layer: name.clone(),
            op: OpKind::Upsample,
            key: true,
            artifact: Some(format!("ups_{name}_{}", ctx.tag)),
            dram_read_bytes: in_b,
            dram_write_bytes: out_b,
            tiles: act_tiles(dv, c, h),
            out_shape: vec![c, h, w],
        }]
    }

    fn logic_cycles(&self, dv: &DesignVars, l: &Layer, op: OpKind)
                    -> u64 {
        let Layer::Pool { c, h, w, k, .. } = *l else { unreachable!() };
        match op {
            OpKind::Pool | OpKind::Upsample => {
                mac_array::pool_cycles(dv, c, h, w, k)
            }
            _ => 0,
        }
    }

    fn phase_cost(&self, dv: &DesignVars, l: &Layer, phase: Phase,
                  _is_first: bool) -> Option<LogicCost> {
        let Layer::Pool { c, h, w, k, .. } = *l else { unreachable!() };
        match phase {
            Phase::Fp | Phase::Bp => {
                let cycles = mac_array::pool_cycles(dv, c, h, w, k);
                Some(LogicCost { cycles, useful_macs: 0,
                                 utilization: 0.0 })
            }
            Phase::Wu => None,
        }
    }

    fn input_row_words(&self, l: &Layer) -> u64 {
        let Layer::Pool { c, w, .. } = *l else { unreachable!() };
        (c * w) as u64
    }

    fn output_row_words(&self, l: &Layer) -> u64 {
        let Layer::Pool { w, k, .. } = *l else { unreachable!() };
        (w / k) as u64
    }

    fn weight_grad_tile_words(&self, _l: &Layer, _dv: &DesignVars)
                              -> u64 {
        0
    }

    fn layer_buffers(&self, l: &Layer, _dv: &DesignVars,
                     out: &mut Vec<BufferSpec>) {
        let Layer::Pool { ref name, c, h, w, k } = *l else {
            unreachable!()
        };
        // per-pool-layer index buffer (2 bits for 2x2 windows)
        let idx_bits = ((k * k) as f64).log2().ceil() as u64;
        out.push(BufferSpec {
            name: format!("idx_{name}"),
            group: BufferGroup::PoolIndex,
            words: (c * (h / k) * (w / k)) as u64,
            bits_per_word: idx_bits.max(1),
            double: false,
        });
    }

    fn control_word(&self, l: &Layer, dv: &DesignVars) -> ControlWord {
        let Layer::Pool { ref name, c, h, w, k } = *l else {
            unreachable!()
        };
        ControlWord {
            layer: name.clone(),
            kind: "pool",
            nif: c,
            nof: c,
            nox: w / k,
            noy: h / k,
            nkx: k,
            tiles_y: h.div_ceil(dv.tile_rows),
            tiles_of: c.div_ceil(dv.pof),
        }
    }
}

// ------------------------------------------------------------------ fc

pub struct FcOps;

impl LayerOps for FcOps {
    fn kind(&self) -> &'static str {
        "fc"
    }

    fn out_geom(&self, l: &Layer) -> Geom {
        let Layer::Fc { cout, .. } = *l else { unreachable!() };
        Geom { c: cout, h: 1, w: 1 }
    }

    fn weight_shape(&self, l: &Layer) -> Option<Vec<usize>> {
        let Layer::Fc { cin, cout, .. } = *l else { unreachable!() };
        Some(vec![cout, cin])
    }

    fn bias_elems(&self, l: &Layer) -> usize {
        let Layer::Fc { cout, .. } = *l else { unreachable!() };
        cout
    }

    fn macs_fp(&self, l: &Layer) -> u64 {
        let Layer::Fc { cin, cout, .. } = *l else { unreachable!() };
        (cin * cout) as u64
    }

    fn macs_wu(&self, l: &Layer) -> u64 {
        self.macs_fp(l)
    }

    fn modules(&self, _l: &Layer) -> Vec<Module> {
        vec![Module::FlattenUnit, Module::FcUnit]
    }

    fn fp_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                -> Vec<Step> {
        let Layer::Fc { ref name, cin, cout } = *l else {
            unreachable!()
        };
        let w_b = ((cin * cout) + cout) as u64 * W16;
        vec![Step {
            phase: Phase::Fp,
            layer: name.clone(),
            op: OpKind::FcFp,
            key: true,
            artifact: Some(format!("fc_fp_{}", ctx.tag)),
            dram_read_bytes: (cin as u64) * W16 + w_b,
            dram_write_bytes: (cout as u64) * W16,
            tiles: cin.div_ceil(dv.pof * dv.tile_rows) as u64 + 1,
            out_shape: vec![cout],
        }]
    }

    fn bp_wu_steps(&self, l: &Layer, dv: &DesignVars, ctx: &StepCtx)
                   -> Vec<Step> {
        let Layer::Fc { ref name, cin, cout } = *l else {
            unreachable!()
        };
        // WU: outer product; gradients accumulate in DRAM (i32)
        let dw_elems = (cin * cout) as u64;
        let mut steps = vec![
            Step {
                phase: Phase::Wu,
                layer: name.clone(),
                op: OpKind::FcWu,
                key: true,
                artifact: Some(format!("fc_wu_{}", ctx.tag)),
                dram_read_bytes: (cin as u64) * W16 + dw_elems * W32,
                dram_write_bytes: dw_elems * W32 + (cout as u64) * W32,
                tiles: cin.div_ceil(dv.pof * dv.tile_rows) as u64 * 2,
                out_shape: vec![cout, cin],
            },
            // BP: transposed weights; the gradient re-enters the
            // feature-map domain with the geometry this layer consumed
            Step {
                phase: Phase::Bp,
                layer: name.clone(),
                op: OpKind::FcBp,
                key: true,
                artifact: Some(format!("fc_bp_{}", ctx.tag)),
                dram_read_bytes: ((cin * cout) as u64 + cout as u64)
                    * W16,
                dram_write_bytes: (cin as u64) * W16,
                tiles: cin.div_ceil(dv.pof * dv.tile_rows) as u64 + 1,
                out_shape: ctx.in_geom.shape(),
            },
        ];
        // consumer-applies-the-mask: a relu-fused layer directly below
        // fc (no pool in between) gets its scaling-unit step here,
        // matching golden::backward's fc-side mask
        if let Some(b) = ctx.below {
            let b_ops = for_layer(b);
            if b_ops.fused_relu(b) {
                let artifact = if b_ops.kind() == "conv" {
                    Some(format!("smask_{}_{}", b.name(), ctx.tag))
                } else {
                    None // BN masks are golden-backend-only
                };
                steps.push(Step {
                    phase: Phase::Bp,
                    layer: name.clone(),
                    op: OpKind::ScaleMask,
                    key: false,
                    artifact,
                    dram_read_bytes: 0,
                    dram_write_bytes: 0,
                    tiles: 0,
                    out_shape: ctx.in_geom.shape(),
                });
            }
        }
        steps
    }

    fn logic_cycles(&self, dv: &DesignVars, l: &Layer, op: OpKind)
                    -> u64 {
        let Layer::Fc { cin, cout, .. } = *l else { unreachable!() };
        match op {
            OpKind::FcFp | OpKind::FcBp | OpKind::FcWu => {
                mac_array::fc_cycles(dv, cin, cout).cycles
            }
            OpKind::WeightUpdate => {
                (self.weight_elems(l) as u64).div_ceil(dv.pof as u64)
            }
            _ => 0,
        }
    }

    fn phase_cost(&self, dv: &DesignVars, l: &Layer, _phase: Phase,
                  _is_first: bool) -> Option<LogicCost> {
        let Layer::Fc { cin, cout, .. } = *l else { unreachable!() };
        Some(mac_array::fc_cycles(dv, cin, cout))
    }

    fn input_row_words(&self, l: &Layer) -> u64 {
        let Layer::Fc { cin, .. } = *l else { unreachable!() };
        cin as u64
    }

    fn output_row_words(&self, l: &Layer) -> u64 {
        let Layer::Fc { cout, .. } = *l else { unreachable!() };
        cout as u64
    }

    fn weight_grad_tile_words(&self, l: &Layer, dv: &DesignVars) -> u64 {
        let Layer::Fc { cin, .. } = *l else { unreachable!() };
        (dv.pof * cin) as u64
    }

    fn control_word(&self, l: &Layer, dv: &DesignVars) -> ControlWord {
        let Layer::Fc { ref name, cin, cout } = *l else {
            unreachable!()
        };
        ControlWord {
            layer: name.clone(),
            kind: "fc",
            nif: cin,
            nof: cout,
            nox: 1,
            noy: 1,
            nkx: 1,
            tiles_y: 1,
            tiles_of: cout.div_ceil(dv.pof),
        }
    }

    fn range_contracts(&self, l: &Layer) -> Vec<AccContract> {
        let Layer::Fc { cin, cout, .. } = *l else { unreachable!() };
        vec![
            AccContract {
                acc: "fp-mac",
                phase: Phase::Fp,
                per_image_raw: BIAS_MAX + cin as i64 * TAP_MAX,
                store_shift: SHIFT_CONV_FP,
                per_batch: false,
                must_stay_exact: false,
            },
            AccContract {
                acc: "bp-mac",
                phase: Phase::Bp,
                per_image_raw: cout as i64 * TAP_MAX,
                store_shift: SHIFT_CONV_BP,
                per_batch: false,
                must_stay_exact: false,
            },
            // fc WU is a single g·x product per weight element, so the
            // only chain is the batch accumulator itself
            AccContract {
                acc: "wgrad-sum",
                phase: Phase::Wu,
                per_image_raw: TAP_MAX,
                store_shift: SHIFT_WU_STORE,
                per_batch: true,
                must_stay_exact: false,
            },
            // db = g directly, one gradient word per image
            AccContract {
                acc: "bgrad-sum",
                phase: Phase::Wu,
                per_image_raw: SAT_MAX,
                store_shift: 0,
                per_batch: true,
                must_stay_exact: false,
            },
        ]
    }
}

// ------------------------------------------------------------------ bn

/// Integer batch normalization (§IV-B, after FxpNet) — the first layer
/// added purely through the registry.  FP normalizes with the running
/// statistics (one multiply + shift + add per pixel; statistics refresh
/// only at batch end, off the critical path) and streams per-image
/// channel sums to the DRAM statistic accumulators; BP scales the
/// gradient by the same constant and accumulates the gamma/beta
/// gradients in the same pass.  Golden-backend numerics live in
/// `nn::bn`.
pub struct BnOps;

impl LayerOps for BnOps {
    fn kind(&self) -> &'static str {
        "bn"
    }

    fn out_geom(&self, l: &Layer) -> Geom {
        let Layer::Bn { c, h, w, .. } = *l else { unreachable!() };
        Geom { c, h, w }
    }

    fn weight_shape(&self, l: &Layer) -> Option<Vec<usize>> {
        let Layer::Bn { c, .. } = *l else { unreachable!() };
        Some(vec![c]) // gamma
    }

    fn bias_elems(&self, l: &Layer) -> usize {
        let Layer::Bn { c, .. } = *l else { unreachable!() };
        c // beta
    }

    fn macs_fp(&self, l: &Layer) -> u64 {
        let Layer::Bn { c, h, w, .. } = *l else { unreachable!() };
        (c * h * w) as u64 // one multiply per pixel
    }

    fn macs_wu(&self, l: &Layer) -> u64 {
        // the gamma-gradient multiply (g * xhat) per pixel
        self.macs_fp(l)
    }

    fn fused_relu(&self, l: &Layer) -> bool {
        let Layer::Bn { relu, .. } = *l else { unreachable!() };
        relu
    }

    fn stat_tensors(&self, l: &Layer) -> Vec<(String, Vec<usize>)> {
        let Layer::Bn { ref name, c, .. } = *l else { unreachable!() };
        // per-batch accumulators of per-image channel means (FA) and
        // second moments (2*FA); merged like gradients, folded into the
        // running statistics at batch end (nn::bn::ema_update)
        vec![
            (format!("sm_{name}"), vec![c]),
            (format!("sq_{name}"), vec![c]),
        ]
    }

    fn state_tensors(&self, l: &Layer) -> Vec<(String, Vec<usize>)> {
        let Layer::Bn { ref name, c, .. } = *l else { unreachable!() };
        // running mean (FA) and variance (2*FA)
        vec![
            (format!("rm_{name}"), vec![c]),
            (format!("rv_{name}"), vec![c]),
        ]
    }

    fn modules(&self, l: &Layer) -> Vec<Module> {
        let mut mods = vec![Module::BatchNormUnit];
        if self.fused_relu(l) {
            mods.push(Module::ReluUnit);
            mods.push(Module::ScalingUnit);
        }
        mods
    }

    fn fp_steps(&self, l: &Layer, dv: &DesignVars, _ctx: &StepCtx)
                -> Vec<Step> {
        let Layer::Bn { ref name, c, h, w, .. } = *l else {
            unreachable!()
        };
        let act_b = (c * h * w) as u64 * W16;
        // per-channel mean/var/gamma/beta registers in, per-image
        // statistic contributions out (i32 accumulators in DRAM)
        let par_b = 4 * c as u64 * W16;
        let stat_b = 2 * c as u64 * W32;
        vec![Step {
            phase: Phase::Fp,
            layer: name.clone(),
            op: OpKind::BnFp,
            key: true,
            artifact: None, // golden-backend-only (no Pallas kernel yet)
            dram_read_bytes: act_b + par_b,
            dram_write_bytes: act_b + stat_b,
            tiles: act_tiles(dv, c, h) + 1,
            out_shape: vec![c, h, w],
        }]
    }

    fn bp_wu_steps(&self, l: &Layer, dv: &DesignVars, _ctx: &StepCtx)
                   -> Vec<Step> {
        let Layer::Bn { ref name, c, h, w, .. } = *l else {
            unreachable!()
        };
        let act_b = (c * h * w) as u64 * W16;
        // statistics-as-constants backward: scale the gradient and
        // fold dgamma/dbeta into their i32 DRAM accumulators in the
        // same pass (read scale + old accumulators, write both back)
        vec![Step {
            phase: Phase::Bp,
            layer: name.clone(),
            op: OpKind::BnBp,
            key: true,
            artifact: None, // golden-backend-only
            dram_read_bytes: act_b + c as u64 * W16 + 2 * c as u64 * W32,
            dram_write_bytes: act_b + 2 * c as u64 * W32,
            tiles: act_tiles(dv, c, h) + 1,
            out_shape: vec![c, h, w],
        }]
    }

    fn logic_cycles(&self, dv: &DesignVars, l: &Layer, op: OpKind)
                    -> u64 {
        let Layer::Bn { c, h, w, .. } = *l else { unreachable!() };
        match op {
            OpKind::BnFp | OpKind::BnBp => {
                mac_array::bn_cycles(dv, c, h, w)
            }
            OpKind::WeightUpdate => {
                (self.weight_elems(l) as u64).div_ceil(dv.pof as u64)
            }
            _ => 0,
        }
    }

    fn phase_cost(&self, dv: &DesignVars, l: &Layer, phase: Phase,
                  _is_first: bool) -> Option<LogicCost> {
        let Layer::Bn { c, h, w, .. } = *l else { unreachable!() };
        match phase {
            Phase::Fp | Phase::Bp => {
                let cycles = mac_array::bn_cycles(dv, c, h, w);
                let useful = (c * h * w) as u64;
                Some(LogicCost {
                    cycles,
                    useful_macs: useful,
                    utilization: useful as f64
                        / (cycles as f64 * dv.mac_count() as f64),
                })
            }
            // gamma/beta gradients ride the BnBp pass
            Phase::Wu => None,
        }
    }

    fn input_row_words(&self, l: &Layer) -> u64 {
        let Layer::Bn { c, w, .. } = *l else { unreachable!() };
        (c * w) as u64 // elementwise: no halo
    }

    fn output_row_words(&self, l: &Layer) -> u64 {
        let Layer::Bn { w, .. } = *l else { unreachable!() };
        w as u64
    }

    fn weight_grad_tile_words(&self, l: &Layer, _dv: &DesignVars)
                              -> u64 {
        let Layer::Bn { c, .. } = *l else { unreachable!() };
        2 * c as u64 // dgamma + dbeta accumulators
    }

    fn layer_buffers(&self, l: &Layer, _dv: &DesignVars,
                     out: &mut Vec<BufferSpec>) {
        let Layer::Bn { ref name, c, h, w, relu } = *l else {
            unreachable!()
        };
        // per-channel statistic/parameter registers: mean, variance,
        // precomputed scale, beta (i32 words so the variance fits)
        out.push(BufferSpec {
            name: format!("bn_{name}"),
            group: BufferGroup::BnStats,
            words: 4 * c as u64,
            bits_per_word: 32,
            double: false,
        });
        if relu {
            out.push(BufferSpec {
                name: format!("mask_{name}"),
                group: BufferGroup::ActGradientMask,
                words: (c * h * w) as u64,
                bits_per_word: 1,
                double: false,
            });
        }
    }

    fn control_word(&self, l: &Layer, dv: &DesignVars) -> ControlWord {
        let Layer::Bn { ref name, c, h, w, .. } = *l else {
            unreachable!()
        };
        ControlWord {
            layer: name.clone(),
            kind: "bn",
            nif: c,
            nof: c,
            nox: w,
            noy: h,
            nkx: 1,
            tiles_y: h.div_ceil(dv.tile_rows),
            tiles_of: c.div_ceil(dv.pof),
        }
    }

    fn range_contracts(&self, l: &Layer) -> Vec<AccContract> {
        let Layer::Bn { h, w, .. } = *l else { unreachable!() };
        let hw = (h * w) as i64;
        vec![
            // sm_* batch accumulator: per-image channel means at FA
            // (hard-bounded by the i16 input range — the per-image sum
            // itself is i64 in `image_stats`, so only the batch sum is
            // an i32).  A wrap poisons the running statistics: gate
            // class.
            AccContract {
                acc: "mean-sum",
                phase: Phase::Fp,
                per_image_raw: SAT_MAX,
                store_shift: 0,
                per_batch: true,
                must_stay_exact: true,
            },
            // sq_* batch accumulator: per-image second moments, hard-
            // bounded by 32768² (a fully saturated image) and stored at
            // 2FA - FQ_SHIFT for wrap headroom.  This is the PR-4 bug
            // class: without the shift the i32 batch sum wraps at 2
            // worst-case images; with it, at 128.
            AccContract {
                acc: "moment-sum",
                phase: Phase::Fp,
                per_image_raw: SAT_MAX * SAT_MAX,
                store_shift: FQ_SHIFT,
                per_batch: true,
                must_stay_exact: true,
            },
            // dgamma per-image chain: Noy·Nox g·xhat products
            // (`backward_params`), shift_round into the i32 DRAM
            // accumulator
            AccContract {
                acc: "wu-mac",
                phase: Phase::Bp,
                per_image_raw: hw * TAP_MAX,
                store_shift: SHIFT_WU_STORE,
                per_batch: false,
                must_stay_exact: false,
            },
            AccContract {
                acc: "wgrad-sum",
                phase: Phase::Bp,
                per_image_raw: hw * TAP_MAX,
                store_shift: SHIFT_WU_STORE,
                per_batch: true,
                must_stay_exact: false,
            },
            // dbeta: plain gradient sum over Noy·Nox per image
            AccContract {
                acc: "bgrad-sum",
                phase: Phase::Bp,
                per_image_raw: hw * SAT_MAX,
                store_shift: 0,
                per_batch: true,
                must_stay_exact: false,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Network;

    #[test]
    fn registry_agrees_with_layer_delegates() {
        // the Layer convenience methods delegate here; the two views
        // must be the same numbers on every layer of both topologies
        for net in [Network::cifar(1), Network::cifar_bn(1)] {
            for l in &net.layers {
                let ops = for_layer(l);
                assert_eq!(ops.out_geom(l).elems(), l.out_elems(),
                           "{}", l.name());
                assert_eq!(ops.weight_elems(l), l.weight_elems());
                assert_eq!(ops.bias_elems(l), l.bias_elems());
                assert_eq!(ops.macs_fp(l), l.macs_fp());
                assert_eq!(ops.macs_bp(l), l.macs_bp());
                assert_eq!(ops.macs_wu(l), l.macs_wu());
                assert_eq!(ops.fused_relu(l), l.fused_relu());
            }
        }
    }

    #[test]
    fn kinds_and_geometry_chain() {
        let net = Network::cifar_bn(1);
        let kinds: Vec<&str> = net
            .layers
            .iter()
            .map(|l| for_layer(l).kind())
            .collect();
        assert_eq!(&kinds[..5], &["conv", "bn", "conv", "bn", "pool"]);
        assert_eq!(*kinds.last().unwrap(), "fc");
        // geometry chains down to the classifier
        let mut geom = Geom { c: 3, h: 32, w: 32 };
        for l in &net.layers {
            assert!(geom.elems() > 0, "degenerate input to {}", l.name());
            geom = for_layer(l).out_geom(l);
        }
        assert_eq!(geom, Geom { c: 10, h: 1, w: 1 });
    }

    #[test]
    fn bn_descriptor_inventory() {
        let net = Network::cifar_bn(1);
        let bn = net
            .layers
            .iter()
            .find(|l| for_layer(l).kind() == "bn")
            .unwrap();
        let ops = for_layer(bn);
        assert_eq!(ops.weight_elems(bn), 16); // gamma
        assert_eq!(ops.bias_elems(bn), 16); // beta
        assert!(ops.fused_relu(bn));
        let stats = ops.stat_tensors(bn);
        assert_eq!(stats.len(), 2);
        assert!(stats[0].0.starts_with("sm_"));
        assert!(stats[1].0.starts_with("sq_"));
        assert_eq!(stats[0].1, vec![16]);
        let states = ops.state_tensors(bn);
        assert_eq!(states.len(), 2);
        assert!(states[0].0.starts_with("rm_"));
        assert!(states[1].0.starts_with("rv_"));
        assert!(ops.modules(bn).contains(&Module::BatchNormUnit));
    }

    #[test]
    fn conv_and_fc_have_no_stats() {
        let net = Network::cifar(1);
        for l in &net.layers {
            assert!(for_layer(l).stat_tensors(l).is_empty());
            assert!(for_layer(l).state_tensors(l).is_empty());
        }
    }

    #[test]
    fn range_contracts_cover_every_accumulating_layer() {
        let net = Network::cifar_bn(1);
        for l in &net.layers {
            let ops = for_layer(l);
            let contracts = ops.range_contracts(l);
            match ops.kind() {
                "pool" => assert!(contracts.is_empty(), "{}", l.name()),
                kind => {
                    assert!(!contracts.is_empty(), "{}", l.name());
                    // every parameterized layer has batch gradient
                    // accumulators; only bn has gate-class rows
                    assert!(contracts.iter().any(|c| c.per_batch));
                    assert_eq!(
                        contracts.iter().any(|c| c.must_stay_exact),
                        kind == "bn",
                        "{}", l.name()
                    );
                }
            }
            for c in &contracts {
                assert!(c.per_image_raw > 0, "{} {}", l.name(), c.acc);
                assert!(c.per_image_stored() <= c.per_image_raw);
            }
        }
    }

    #[test]
    fn bn_moment_contract_matches_the_kernel_headroom() {
        // the sq_* contract must agree with nn::bn's documented bound:
        // a saturated image contributes 2^(2·FA_bits) >> FQ_SHIFT =
        // 2^24, so the i32 batch sum first wraps at 128 images
        let l = Layer::Bn {
            name: "n".into(), c: 4, h: 8, w: 8, relu: true,
        };
        let moment = for_layer(&l)
            .range_contracts(&l)
            .into_iter()
            .find(|c| c.acc == "moment-sum")
            .unwrap();
        assert!(moment.must_stay_exact);
        assert_eq!(moment.per_image_stored(), 1 << 24);
        assert_eq!(i64::from(i32::MAX) / moment.per_image_stored(), 127);
    }
}
