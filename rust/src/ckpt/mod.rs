//! Crash-safe training checkpoints: a versioned, CRC-guarded binary
//! snapshot of everything the trainer needs to restart *bit-identically*
//! — parameters, the full optimizer state (gradient accumulators +
//! momentum + per-batch counts), the rolling [`TrainMetrics`], a
//! fingerprint of the network/design/hyper-parameters, and the dataset
//! cursor.
//!
//! # Why this can promise bit-identical restarts
//!
//! Every quantity the training loop evolves is either an integer tensor
//! (params, accumulators, momentum — restored exactly), an exact i64/u64
//! counter, or an f64 running sum restored from its raw bits
//! ([`f64::to_bits`]), after which the resumed run appends the *same*
//! addends in the *same* order as an uninterrupted run.  The dataset
//! ([`crate::data::Synthetic`]) is a pure function of `(seed, index)`,
//! so the cursor is just four integers: epoch, next batch index, seed,
//! and the epoch width in images (batch indices are only meaningful
//! relative to it).
//! Combined with the engine/cluster determinism contract (merge order
//! fixed at any `--workers`/`--accelerators` count), *resumed training
//! is bit-for-bit identical to never having stopped* — asserted by
//! `rust/tests/ckpt.rs`.
//!
//! # On-disk layout (`CKPT_VERSION` 1)
//!
//! ```text
//! [0..4)    magic  b"SCKP"
//! [4..8)    format version, u32 LE
//! [8..n-4)  payload: an FXTB tensor bundle (nn::tensorio::Bundle)
//! [n-4..n)  CRC-32 (IEEE) of bytes [0..n-4), u32 LE
//! ```
//!
//! The payload reuses the [`Bundle`] framing with a flat namespace:
//! `meta/*` tensors carry the cursor/hyper/metrics/fingerprint (u64 and
//! f64 values split into i32 lo/hi words), `param/<name>` the parameter
//! tensors (canonical `param_order`, then the BN running statistics
//! `rm_*`/`rv_*` from `state_order`), and `state.grad/<name>` /
//! `state.mom/<name>` / `state.meta/<name>` the optimizer and
//! statistic-accumulator states in the trainer's `accum_order`.  Params
//! and states are independent namespaces: BN running statistics are
//! params without states, BN shard-sum accumulators (`sm_*`/`sq_*`,
//! kind `Stat`) are states without params.
//!
//! Writes are atomic and durable: the bytes go to a `<file>.tmp`
//! sibling (fsync'd) which is then renamed over the target, and the
//! parent directory is fsync'd so the rename survives power loss — a
//! crash mid-write can never leave a half-written checkpoint where the
//! next `--resume` would find it, and even a torn file is caught by
//! the CRC trailer, which rejects truncated or corrupted files instead
//! of half-loading them.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::TrainMetrics;
use crate::nn::sgd::{ParamKind, ParamState, SgdHyper};
use crate::nn::tensor::Tensor;
use crate::nn::tensorio::Bundle;

/// Checkpoint container magic ("Stratus ChecKPoint").
pub const MAGIC: &[u8; 4] = b"SCKP";

/// On-disk format version; bump on any layout change.
pub const CKPT_VERSION: u32 = 1;

/// Where training stood when the checkpoint was taken: the *next* batch
/// to run.  `batch` indexes batches within the epoch (0-based); an
/// epoch boundary is always normalized to `(epoch + 1, 0)`.  `seed` is
/// the synthetic-dataset seed and `images` the epoch width — together
/// with the indices they fully determine every remaining sample (the
/// dataset cursor from the module docs; a batch index is only
/// meaningful relative to the epoch width, so `images` rides along and
/// a resume with a different `--images` is refused rather than
/// silently retraining over a different data window).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cursor {
    pub epoch: u64,
    pub batch: u64,
    pub seed: u64,
    pub images: u64,
}

impl Cursor {
    /// The cursor before any training: epoch 0, batch 0.
    pub fn start(seed: u64, images: u64) -> Cursor {
        Cursor { epoch: 0, batch: 0, seed, images }
    }
}

/// A full training snapshot (see module docs for the field inventory).
/// Parameters and optimizer states are stored in the network's
/// canonical `param_order`; the fingerprint refuses resumption onto a
/// different network / design point / hyper-parameters.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub fingerprint: String,
    pub cursor: Cursor,
    pub hyper: SgdHyper,
    pub metrics: TrainMetrics,
    /// `(name, tensor)` in canonical order.
    pub params: Vec<(String, Tensor)>,
    /// `(name, state)` in canonical order.
    pub states: Vec<(String, ParamState)>,
}

// ---------------- integer/float packing ----------------

fn split_u64(v: u64) -> [i32; 2] {
    [(v & 0xFFFF_FFFF) as u32 as i32, (v >> 32) as u32 as i32]
}

fn join_u64(lo: i32, hi: i32) -> u64 {
    u64::from(lo as u32) | (u64::from(hi as u32) << 32)
}

fn split_f64(v: f64) -> [i32; 2] {
    split_u64(v.to_bits())
}

fn join_f64(lo: i32, hi: i32) -> f64 {
    f64::from_bits(join_u64(lo, hi))
}

/// CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — the guard on
/// the checkpoint trailer.  Bitwise implementation: checkpoints are
/// megabytes at most and written once per N batches, so table-free
/// simplicity wins over throughput.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Checkpoint {
    /// Serialize to the on-disk byte layout (module docs), borrowing
    /// wrapper for tests/tools; the save path uses the consuming
    /// [`Checkpoint::into_bytes`] so no tensor is copied twice.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.clone().into_bytes()
    }

    /// Serialize to the on-disk byte layout, consuming the snapshot —
    /// every parameter/state tensor moves into the payload bundle
    /// instead of being cloned a second time (the checkpoint cadence
    /// sits on the training loop's hot path).
    pub fn into_bytes(self) -> Vec<u8> {
        let mut bundle = Bundle::new();
        let fp_bytes: Vec<i32> = self
            .fingerprint
            .as_bytes()
            .iter()
            .map(|&b| i32::from(b))
            .collect();
        let n_fp = fp_bytes.len();
        bundle.push("meta/fingerprint",
                    Tensor::from_vec(&[n_fp], fp_bytes));
        let c = &self.cursor;
        let cur: Vec<i32> = [c.epoch, c.batch, c.seed, c.images]
            .iter()
            .flat_map(|&v| split_u64(v))
            .collect();
        bundle.push("meta/cursor", Tensor::from_vec(&[8], cur));
        let [b_lo, b_hi] = split_u64(self.hyper.batch as u64);
        bundle.push("meta/hyper",
                    Tensor::from_vec(&[4],
                                     vec![self.hyper.lr_q16,
                                          self.hyper.beta_q15, b_lo,
                                          b_hi]));
        let m = &self.metrics;
        let mut mm = Vec::with_capacity(10);
        mm.extend_from_slice(&split_u64(m.images));
        mm.extend_from_slice(&split_u64(m.batches));
        mm.extend_from_slice(&split_f64(m.loss_sum));
        mm.extend_from_slice(&split_f64(m.sim_cycles));
        mm.extend_from_slice(&split_f64(m.host_seconds));
        bundle.push("meta/metrics", Tensor::from_vec(&[10], mm));
        for (name, t) in self.params {
            bundle.push(&format!("param/{name}"), t);
        }
        for (name, st) in self.states {
            let kind = match st.kind {
                ParamKind::Weight => 0,
                ParamKind::Bias => 1,
                ParamKind::Stat => 2,
            };
            let [c_lo, c_hi] = split_u64(st.count as u64);
            bundle.push(&format!("state.grad/{name}"), st.grad_acc);
            bundle.push(&format!("state.mom/{name}"), st.momentum);
            bundle.push(&format!("state.meta/{name}"),
                        Tensor::from_vec(&[3], vec![kind, c_lo, c_hi]));
        }

        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        out.extend_from_slice(&bundle.to_bytes());
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and fully validate a checkpoint: magic, version, CRC, and
    /// the presence/shape of every metadata tensor.  A truncated or
    /// bit-flipped file is rejected here — never half-loaded.
    pub fn from_bytes(blob: &[u8]) -> Result<Checkpoint> {
        if blob.len() < 12 {
            bail!("checkpoint truncated ({} bytes; a valid file is at \
                   least 12)",
                  blob.len());
        }
        if &blob[0..4] != MAGIC {
            bail!("bad checkpoint magic (expected SCKP)");
        }
        let version =
            u32::from_le_bytes([blob[4], blob[5], blob[6], blob[7]]);
        if version != CKPT_VERSION {
            bail!("unsupported checkpoint format version {version} \
                   (this build reads version {CKPT_VERSION})");
        }
        let body = &blob[..blob.len() - 4];
        let tail = &blob[blob.len() - 4..];
        let stored =
            u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let computed = crc32(body);
        if stored != computed {
            bail!("checkpoint CRC checksum mismatch (stored {stored:#010x}, \
                   computed {computed:#010x}); the file is truncated or \
                   corrupted — refusing to load it");
        }
        let bundle = Bundle::from_bytes(&body[8..])
            .context("parsing checkpoint payload bundle")?;

        let fp_t = bundle.get_req("meta/fingerprint")?;
        let fp_bytes: Vec<u8> = fp_t
            .data()
            .iter()
            .map(|&v| {
                u8::try_from(v).map_err(|_| {
                    anyhow!("checkpoint fingerprint holds non-byte \
                             value {v}")
                })
            })
            .collect::<Result<_>>()?;
        let fingerprint = String::from_utf8(fp_bytes)
            .context("checkpoint fingerprint is not utf8")?;

        let cur = bundle.get_req("meta/cursor")?;
        if cur.len() != 8 {
            bail!("checkpoint cursor has {} words (expected 8)",
                  cur.len());
        }
        let cd = cur.data();
        let cursor = Cursor {
            epoch: join_u64(cd[0], cd[1]),
            batch: join_u64(cd[2], cd[3]),
            seed: join_u64(cd[4], cd[5]),
            images: join_u64(cd[6], cd[7]),
        };

        let hy = bundle.get_req("meta/hyper")?;
        if hy.len() != 4 {
            bail!("checkpoint hyper has {} words (expected 4)", hy.len());
        }
        let hd = hy.data();
        let batch = usize::try_from(join_u64(hd[2], hd[3]))
            .map_err(|_| anyhow!("checkpoint batch size overflows"))?;
        let hyper =
            SgdHyper { lr_q16: hd[0], beta_q15: hd[1], batch };

        let mt = bundle.get_req("meta/metrics")?;
        if mt.len() != 10 {
            bail!("checkpoint metrics has {} words (expected 10)",
                  mt.len());
        }
        let md = mt.data();
        let metrics = TrainMetrics {
            images: join_u64(md[0], md[1]),
            batches: join_u64(md[2], md[3]),
            loss_sum: join_f64(md[4], md[5]),
            sim_cycles: join_f64(md[6], md[7]),
            host_seconds: join_f64(md[8], md[9]),
            // the compute/comm split is session-local telemetry and is
            // deliberately not serialized (the tensor stays 10 words,
            // byte-compatible with every existing checkpoint)
            ..TrainMetrics::default()
        };

        // params and optimizer states, preserving bundle order (which
        // is the canonical order the writer used).  States are scanned
        // by their own prefix rather than derived from the param list:
        // BN running statistics are params without states, and BN
        // statistic accumulators are states without params.
        let mut params = Vec::new();
        let mut states = Vec::new();
        for name in bundle.names() {
            if let Some(p) = name.strip_prefix("param/") {
                params.push((p.to_string(),
                             bundle.get_req(name)?.clone()));
            }
        }
        for full in bundle.names() {
            let Some(name) = full.strip_prefix("state.grad/") else {
                continue;
            };
            let grad_acc = bundle.get_req(full)?.clone();
            let momentum =
                bundle.get_req(&format!("state.mom/{name}"))?.clone();
            let sm = bundle.get_req(&format!("state.meta/{name}"))?;
            if sm.len() != 3 {
                bail!("checkpoint state.meta/{name} has {} words \
                       (expected 3)",
                      sm.len());
            }
            let sd = sm.data();
            let kind = match sd[0] {
                0 => ParamKind::Weight,
                1 => ParamKind::Bias,
                2 => ParamKind::Stat,
                other => bail!("checkpoint state.meta/{name} has \
                                unknown param kind {other}"),
            };
            let count = usize::try_from(join_u64(sd[1], sd[2]))
                .map_err(|_| anyhow!("state count overflows"))?;
            let st =
                ParamState::from_snapshot(kind, grad_acc, momentum,
                                          count)
                    .with_context(|| format!("restoring state {name}"))?;
            states.push((name.to_string(), st));
        }
        if params.is_empty() {
            bail!("checkpoint holds no parameters");
        }
        Ok(Checkpoint {
            fingerprint,
            cursor,
            hyper,
            metrics,
            params,
            states,
        })
    }

    /// Atomically write the checkpoint to `path` (consuming it — see
    /// [`Checkpoint::into_bytes`]): the bytes land in a `<file>.tmp`
    /// sibling first, fsync'd, and are renamed into place, and the
    /// parent directory is fsync'd too so the rename itself is durable
    /// — a crash at any point leaves either the previous checkpoint or
    /// the new one, never a torn or vanished file.
    pub fn save_atomic(self, path: &Path) -> Result<()> {
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| {
                anyhow!("checkpoint path {} has no file name",
                        path.display())
            })?;
        let tmp = path.with_file_name(format!("{file_name}.tmp"));
        {
            let mut f = fs::File::create(&tmp).with_context(|| {
                format!("creating {}", tmp.display())
            })?;
            f.write_all(&self.into_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, path).with_context(|| {
            format!("renaming {} -> {}", tmp.display(), path.display())
        })?;
        // make the rename durable: fsync the directory holding the
        // entry (an empty parent means the path is a bare file name
        // in the current directory)
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        fs::File::open(parent)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("syncing {}", parent.display()))?;
        Ok(())
    }

    /// Load and validate a checkpoint file (see [`Checkpoint::from_bytes`]
    /// for what validation covers).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let blob = fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Checkpoint::from_bytes(&blob)
            .with_context(|| format!("loading {}", path.display()))
    }
}

/// Load just the cursor of the checkpoint at `path`.  Full validation
/// still applies — a torn or corrupt file is rejected, never half
/// read.  The serve queue-recovery scan uses this to report where
/// each interrupted run will resume without restoring a trainer.
pub fn peek_cursor(path: &Path) -> Result<Cursor> {
    Ok(Checkpoint::load(path)?.cursor)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_checkpoint() -> Checkpoint {
        let w = Tensor::from_vec(&[2, 2], vec![1, -2, 3, i32::MIN]);
        let b = Tensor::from_vec(&[2], vec![7, -7]);
        let mut sw = ParamState::new(ParamKind::Weight, &[2, 2]);
        sw.accumulate(&Tensor::from_vec(&[2, 2],
                                        vec![5, 6, 7, i32::MAX]));
        let sb = ParamState::new(ParamKind::Bias, &[2]);
        Checkpoint {
            fingerprint: "net tiny | dv 8x8x16".to_string(),
            cursor: Cursor { epoch: 3, batch: 11, seed: 42,
                             images: 2048 },
            hyper: SgdHyper::new(0.002, 0.9, 40),
            metrics: TrainMetrics {
                images: u64::from(u32::MAX) + 5,
                batches: 17,
                loss_sum: 1234.5678,
                sim_cycles: 9.87e12,
                host_seconds: 0.25,
                ..TrainMetrics::default()
            },
            params: vec![("w_c1".to_string(), w),
                         ("b_c1".to_string(), b)],
            states: vec![("w_c1".to_string(), sw),
                         ("b_c1".to_string(), sb)],
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn u64_f64_packing_round_trips() {
        for v in [0u64, 1, u64::from(u32::MAX), u64::MAX, 1 << 33] {
            let [lo, hi] = split_u64(v);
            assert_eq!(join_u64(lo, hi), v);
        }
        for v in [0.0f64, -1.5, f64::MIN_POSITIVE, 1.0e300,
                  -0.1234567890123456789] {
            let [lo, hi] = split_f64(v);
            assert_eq!(join_f64(lo, hi).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn checkpoint_round_trips_bit_exactly() {
        let ck = sample_checkpoint();
        let blob = ck.to_bytes();
        let r = Checkpoint::from_bytes(&blob).unwrap();
        assert_eq!(r.fingerprint, ck.fingerprint);
        assert_eq!(r.cursor, ck.cursor);
        assert_eq!(r.hyper.lr_q16, ck.hyper.lr_q16);
        assert_eq!(r.hyper.beta_q15, ck.hyper.beta_q15);
        assert_eq!(r.hyper.batch, ck.hyper.batch);
        assert_eq!(r.metrics.images, ck.metrics.images);
        assert_eq!(r.metrics.batches, ck.metrics.batches);
        assert_eq!(r.metrics.loss_sum.to_bits(),
                   ck.metrics.loss_sum.to_bits());
        assert_eq!(r.metrics.sim_cycles.to_bits(),
                   ck.metrics.sim_cycles.to_bits());
        assert_eq!(r.params.len(), 2);
        assert_eq!(r.params[0].0, "w_c1");
        assert_eq!(r.params[0].1, ck.params[0].1);
        assert_eq!(r.states[0].1.grad_acc, ck.states[0].1.grad_acc);
        assert_eq!(r.states[0].1.momentum, ck.states[0].1.momentum);
        assert_eq!(r.states[0].1.count, ck.states[0].1.count);
        assert_eq!(r.states[1].1.kind, ParamKind::Bias);
    }

    #[test]
    fn stat_states_and_stateless_params_round_trip() {
        // BN shape: a running-stat param with no state, and a Stat
        // accumulator state with no param
        let mut ck = sample_checkpoint();
        ck.params
            .push(("rm_n1".to_string(),
                   Tensor::from_vec(&[2], vec![3, -9])));
        let mut st = ParamState::new(ParamKind::Stat, &[2]);
        st.accumulate(&Tensor::from_vec(&[2], vec![512, 1024]));
        ck.states.push(("sm_n1".to_string(), st));
        let r = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(r.params.len(), 3);
        assert_eq!(r.params[2].0, "rm_n1");
        assert_eq!(r.params[2].1.data(), &[3, -9]);
        assert_eq!(r.states.len(), 3);
        assert_eq!(r.states[2].0, "sm_n1");
        assert_eq!(r.states[2].1.kind, ParamKind::Stat);
        assert_eq!(r.states[2].1.grad_acc.data(), &[512, 1024]);
        assert_eq!(r.states[2].1.count, 1);
    }

    #[test]
    fn rejects_truncation_at_any_cut() {
        let blob = sample_checkpoint().to_bytes();
        for cut in [0, 3, 8, 11, blob.len() / 2, blob.len() - 1] {
            assert!(Checkpoint::from_bytes(&blob[..cut]).is_err(),
                    "cut={cut} accepted");
        }
    }

    #[test]
    fn rejects_any_bit_flip() {
        let blob = sample_checkpoint().to_bytes();
        // flip one bit at several offsets across the file, including
        // payload and trailer bytes
        for off in [0, 5, 9, blob.len() / 3, blob.len() - 2] {
            let mut bad = blob.clone();
            bad[off] ^= 0x10;
            assert!(Checkpoint::from_bytes(&bad).is_err(),
                    "bit flip at {off} accepted");
        }
    }

    #[test]
    fn rejects_future_version() {
        let mut blob = sample_checkpoint().to_bytes();
        blob[4] = 99; // version field
        // restore the CRC so only the version check can fire
        let n = blob.len();
        let crc = crc32(&blob[..n - 4]);
        blob[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = Checkpoint::from_bytes(&blob).unwrap_err();
        assert!(format!("{err:#}").contains("version"));
    }

    #[test]
    fn save_is_atomic_and_leaves_no_tmp() {
        let dir = std::env::temp_dir()
            .join(format!("stratus_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.stratus");
        let ck = sample_checkpoint();
        ck.clone().save_atomic(&path).unwrap();
        // overwrite in place (the crash-safety path: rename over)
        ck.clone().save_atomic(&path).unwrap();
        let r = Checkpoint::load(&path).unwrap();
        assert_eq!(r.cursor, ck.cursor);
        assert!(!path.with_file_name("ckpt.stratus.tmp").exists(),
                "tmp file left behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn peek_cursor_reads_and_still_validates() {
        let dir = std::env::temp_dir()
            .join(format!("stratus_peek_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.stratus");
        let ck = sample_checkpoint();
        let want = ck.cursor;
        ck.save_atomic(&path).unwrap();
        assert_eq!(peek_cursor(&path).unwrap(), want);
        // corruption is rejected, not half-read
        let mut blob = std::fs::read(&path).unwrap();
        let last = blob.len() - 1;
        blob[last] ^= 0xFF;
        std::fs::write(&path, &blob).unwrap();
        assert!(peek_cursor(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
