//! In-tree stand-in for the `xla` FFI crate (PJRT / xla_extension
//! bindings).
//!
//! The offline toolchain vendors no FFI crates, so the runtime compiles
//! against this API-compatible stub instead of the real bindings: every
//! entry point type-checks exactly like the call sites in
//! [`super`](crate::runtime) expect, and the only reachable failure is
//! [`PjRtClient::cpu`], which reports that PJRT support is not compiled
//! into this build.  Host-side literal handling ([`Literal::vec1`],
//! [`Literal::reshape`], [`Literal::to_vec`]) is implemented for real so
//! shape plumbing and the parameter-literal cache stay testable.
//!
//! The golden backend (pure rust, bit-identical to the AOT artifacts by
//! construction) is unaffected; integration tests that need artifacts
//! detect the missing `artifacts/` directory and skip themselves.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring the FFI crate's; call sites format it with
/// `{e:?}`, so `Debug` renders the human-readable message directly.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

type XlaResult<T> = Result<T, Error>;

fn unavailable<T>(what: &str) -> XlaResult<T> {
    Err(Error(format!(
        "{what}: PJRT support is not compiled into this build (the \
         `xla` FFI crate is unavailable in the offline toolchain); use \
         the golden backend"
    )))
}

/// Host literal.  This system only ever moves int32 payloads.
pub struct Literal {
    data: Vec<i32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a borrowed buffer.
    pub fn vec1(data: &[i32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions of equal element count.
    pub fn reshape(self, dims: &[i64]) -> XlaResult<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data, dims: dims.to_vec() })
    }

    /// Destructure a tuple literal (device results are always tuples).
    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        unavailable("to_tuple")
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: From<i32>>(&self) -> XlaResult<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from(v)).collect())
    }
}

/// Parsed HLO module (text interchange; see runtime module docs).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _c: &XlaComputation)
                   -> XlaResult<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T])
                      -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device-resident result buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_counts() {
        let l = Literal::vec1(&[1, 2, 3, 4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims, vec![2, 2]);
        assert!(Literal::vec1(&[1, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn literal_roundtrips_host_data() {
        let l = Literal::vec1(&[5, -6, 7]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -6, 7]);
    }

    #[test]
    fn client_reports_missing_pjrt() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("PJRT support"));
    }
}
