//! PJRT runtime: loads the HLO-text artifacts lowered by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client,
//! and executes them from the coordinator's hot path.  Python never runs
//! here — the artifacts directory is the entire contract.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! This build links against the in-tree [`xla`] stub (the FFI crate is
//! not vendored in the offline toolchain): [`Runtime::open`] fails with
//! a clear message after manifest validation, and everything that needs
//! artifacts degrades to the golden backend.  Swapping the `mod xla`
//! line for the real crate restores PJRT execution unchanged.

mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::Json;
use crate::nn::tensor::Tensor;

/// Signature of one artifact op (from manifest.json).
#[derive(Debug, Clone)]
pub struct OpSig {
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub ops: HashMap<String, OpSig>,
    /// Q-format fraction bits (fa, fw, fg, fwg, fv) — checked against the
    /// rust `fixed` constants at load.
    pub qformat: (u32, u32, u32, u32, u32),
    /// scale tag -> (params file, testvec file)
    pub nets: HashMap<String, (String, String)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let q = j.get("qformat").ok_or_else(|| anyhow!("no qformat"))?;
        let get_q = |k: &str| -> Result<u32> {
            q.get(k)
                .and_then(Json::as_usize)
                .map(|v| v as u32)
                .ok_or_else(|| anyhow!("qformat.{k} missing"))
        };
        let qformat = (get_q("fa")?, get_q("fw")?, get_q("fg")?,
                       get_q("fwg")?, get_q("fv")?);

        let mut ops = HashMap::new();
        let jops = j
            .get("ops")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("no ops object"))?;
        for (name, op) in jops {
            let file = op
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: no file"))?
                .to_string();
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                op.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter().filter_map(|s| s.as_shape()).collect()
                    })
                    .ok_or_else(|| anyhow!("{name}: no {key}"))
            };
            ops.insert(
                name.clone(),
                OpSig { file, inputs: shapes("inputs")?,
                        outputs: shapes("outputs")? },
            );
        }

        let mut nets = HashMap::new();
        if let Some(jnets) = j.get("nets").and_then(Json::as_obj) {
            for (scale, n) in jnets {
                let pf = n
                    .get("params_file")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string();
                let tf = n
                    .get("testvec_file")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string();
                nets.insert(scale.clone(), (pf, tf));
            }
        }
        Ok(Manifest { ops, qformat, nets })
    }
}

/// A host literal pre-converted from a [`Tensor`], reusable across many
/// executions (the coordinator caches parameter literals for a whole
/// batch — §Perf: conversion was ~20% of per-op step time).
pub struct Prepared {
    lit: xla::Literal,
    shape: Vec<usize>,
}

/// Input to [`Runtime::execute_prepared`]: borrowed tensor (converted on
/// the fly) or a cached [`Prepared`] literal.
pub enum In<'a> {
    T(&'a Tensor),
    P(&'a Prepared),
}

/// The PJRT-backed artifact executor.  Executables compile lazily on
/// first use and are cached for the lifetime of the runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executed-op counter (coordinator metrics).
    pub executions: Mutex<u64>,
}

impl Runtime {
    /// Open an artifacts directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts`",
                    dir.display()
                )
            })?;
        let manifest = Manifest::parse(&text)?;
        // fail fast if the artifacts were built with different Q formats
        let want = (
            crate::fixed::FA,
            crate::fixed::FW,
            crate::fixed::FG,
            crate::fixed::FWG,
            crate::fixed::FV,
        );
        if manifest.qformat != want {
            bail!(
                "artifact Q-format {:?} != rust Q-format {:?}; rebuild \
                 artifacts",
                manifest.qformat,
                want
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
            executions: Mutex::new(0),
        })
    }

    /// Number of distinct compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Eagerly compile a set of ops (startup warming).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, op: &str) -> Result<()> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains_key(op) {
                return Ok(());
            }
        }
        let sig = self
            .manifest
            .ops
            .get(op)
            .ok_or_else(|| anyhow!("unknown artifact op `{op}`"))?;
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {op}: {e:?}"))?;
        self.cache.lock().unwrap().insert(op.to_string(), exe);
        Ok(())
    }

    /// Convert a tensor into a reusable device-ready literal.
    pub fn prepare(&self, t: &Tensor) -> Result<Prepared> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(t.data())
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        Ok(Prepared { lit, shape: t.shape().to_vec() })
    }

    /// Execute an artifact op on int32 tensors; shape-checked against the
    /// manifest signature on both sides.
    pub fn execute(&self, op: &str, inputs: &[&Tensor])
                   -> Result<Vec<Tensor>> {
        let ins: Vec<In> = inputs.iter().map(|t| In::T(t)).collect();
        self.execute_prepared(op, &ins)
    }

    /// Execute with a mix of raw tensors and pre-converted literals.
    pub fn execute_prepared(&self, op: &str, inputs: &[In])
                            -> Result<Vec<Tensor>> {
        let sig = self
            .manifest
            .ops
            .get(op)
            .ok_or_else(|| anyhow!("unknown artifact op `{op}`"))?
            .clone();
        if inputs.len() != sig.inputs.len() {
            bail!(
                "{op}: {} inputs given, {} expected",
                inputs.len(),
                sig.inputs.len()
            );
        }
        for (i, (inp, want)) in inputs.iter().zip(&sig.inputs).enumerate()
        {
            let shape: &[usize] = match inp {
                In::T(t) => t.shape(),
                In::P(p) => &p.shape,
            };
            if shape != &want[..] {
                bail!(
                    "{op}: input {i} shape {:?} != manifest {:?}",
                    shape,
                    want
                );
            }
        }
        self.ensure_compiled(op)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(op).unwrap();

        // convert only the raw-tensor inputs; reuse prepared literals
        let mut owned: Vec<Option<xla::Literal>> = Vec::new();
        for inp in inputs {
            owned.push(match inp {
                In::T(t) => {
                    let dims: Vec<i64> =
                        t.shape().iter().map(|&d| d as i64).collect();
                    Some(
                        xla::Literal::vec1(t.data())
                            .reshape(&dims)
                            .map_err(|e| anyhow!("reshape: {e:?}"))?,
                    )
                }
                In::P(_) => None,
            });
        }
        let literals: Vec<&xla::Literal> = inputs
            .iter()
            .zip(&owned)
            .map(|(inp, o)| match inp {
                In::T(_) => o.as_ref().unwrap(),
                In::P(p) => &p.lit,
            })
            .collect();

        let result = exe
            .execute::<&xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {op}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {op} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling {op}: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{op}: {} outputs, manifest says {}",
                parts.len(),
                sig.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, shape) in parts.iter().zip(&sig.outputs) {
            let data = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("{op} output to_vec: {e:?}"))?;
            outs.push(Tensor::from_vec(shape, data));
        }
        *self.executions.lock().unwrap() += 1;
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_minimal() {
        let text = r#"{
            "qformat": {"fa":8,"fw":12,"fg":12,"fwg":16,"fv":16},
            "ops": {"x": {"file":"x.hlo.txt","inputs":[[2,2]],
                          "outputs":[[2,2]]}},
            "nets": {"1x": {"params_file":"p.bin","testvec_file":"t.bin"}}
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.qformat, (8, 12, 12, 16, 16));
        assert_eq!(m.ops["x"].inputs, vec![vec![2, 2]]);
        assert_eq!(m.nets["1x"].0, "p.bin");
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"qformat":{"fa":8}}"#).is_err());
    }
}
