//! Bench: regenerate Fig. 9 (latency breakdown of CIFAR-10 4X across FP /
//! BP / WU phases, logic vs DRAM, last iteration of a batch).
//! `cargo bench --bench fig9`

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::metrics::fig9;
use stratus::sim::{per_layer_latency, simulate};

fn main() {
    println!("=== Fig. 9 (reproduced): 4X phase breakdown ===");
    println!("{}", fig9());

    let acc = RtlCompiler::default()
        .compile(&Network::cifar(4), &DesignVars::for_scale(4))
        .unwrap();
    let r = simulate(&acc, 40);

    // paper claim: 51% of one batch-iteration latency is in the weight
    // update layers (WU convolutions + batch weight update)
    let wu = r.wu.latency_cycles as f64
        + r.update.latency_cycles as f64 / r.batch_size as f64;
    let frac = wu / r.cycles_per_image();
    println!("WU-layer share of one iteration: {:.1}% (paper: 51%)",
             frac * 100.0);

    // per-layer detail (the bars of Fig. 9)
    println!("\nper-layer latency cycles [FP, BP, WU]:");
    let t = per_layer_latency(&r);
    let mut names: Vec<&String> = t.keys().collect();
    names.sort();
    for n in names {
        let [fp, bp, wu] = t[n];
        println!("  {n:<4} {fp:>9} {bp:>9} {wu:>9}");
    }
    println!("\nDRAM-vs-logic: WU dram cycles {} vs logic {} \
              (paper: WU layers dominated by DRAM access)",
             r.wu.dram_cycles, r.wu.logic_cycles);
}
