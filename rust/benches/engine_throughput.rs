//! Engine throughput bench (ISSUE 1 tentpole): host-side scaling of the
//! batch-parallel training engine on the golden backend — per-image
//! latency and images/sec at 1/2/4/8 workers with a bit-identity check
//! against the sequential path — plus the hardware model's projection
//! for the same sharding across replicated accelerator instances.
//!
//! `cargo bench --bench engine_throughput [-- --smoke]`: smoke mode
//! (also `BENCH_SMOKE=1`) runs one batch per worker count for CI.  The
//! bench writes `BENCH_engine_throughput.json` and exits nonzero when
//! the headline `images_per_second` regresses more than 30% below
//! `benches/baseline.json`, or on a bit-identity mismatch
//! (metrics::bench::ScalingBench).

use std::time::Instant;

use stratus::data::Synthetic;
use stratus::metrics::bench::{smoke_mode, ScalingBench};
use stratus::metrics::engine_scaling;
use stratus::session::{Session, Spec};

const NET_CFG: &str = "input 3 16 16\nconv c1 8 k3 s1 p1 relu\n\
                       conv c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                       loss hinge";

fn main() {
    let smoke = smoke_mode();
    let data = Synthetic::new(10, (3, 16, 16), 17, 0.3);
    let batch_size = 32;
    let batches = if smoke { 1 } else { 4 };
    let train = data.batch(0, batch_size * batches);

    println!("=== batch-parallel engine: host throughput{} ===",
             if smoke { " (smoke)" } else { "" });
    println!("{:<8} {:>10} {:>12} {:>9} {:>14}", "workers", "images/s",
             "ms/image", "speedup", "vs sequential");
    let mut bench = ScalingBench::new("engine_throughput", smoke);
    for workers in [1usize, 2, 4, 8] {
        let spec = Spec::builder()
            .net_inline(NET_CFG)
            .batch(batch_size)
            .lr(0.02)
            .momentum(0.9)
            .workers(workers)
            .build()
            .unwrap();
        let mut t = Session::new(spec).unwrap().trainer().unwrap();
        // warmup batch (identical across worker counts, so final
        // params stay comparable); keeps the two scaling benches'
        // measurement protocol symmetric
        t.train_batch(&train[..batch_size]).unwrap();
        let t0 = Instant::now();
        for chunk in train.chunks(batch_size) {
            t.train_batch(chunk).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let n = train.len() as f64;
        let ips = n / dt;
        let (speedup, verdict) = bench.observe(ips, t.flat_params());
        println!("{:<8} {:>10.1} {:>12.3} {:>8.2}x {:>14}", workers, ips,
                 dt / n * 1e3, speedup, verdict);
    }

    println!("\n=== hardware model: sharded accelerator instances \
              (1X @ BS 40) ===");
    println!("{}", engine_scaling(1, 40, &[1, 2, 4, 8, 16]));

    let paper = Session::new(
        Spec::builder().preset("1x").batch(40).build().unwrap(),
    )
    .unwrap();
    let r = paper.simulate().unwrap();
    println!("single-instance per-image latency: {:.3} ms ({:.0} \
              images/s)",
             r.seconds_per_image() * 1e3, r.images_per_second());

    std::process::exit(bench.finish(&[
        ("batch_size", batch_size as f64),
        ("batches", batches as f64),
    ]));
}
