//! Ablation bench (Fig. 5 / §III-D): the transposable circulant weight
//! buffer vs a naive single-port store — BP-order streaming latency and
//! storage cost, plus functional wall-clock of the buffer itself.
//! `cargo bench --bench ablation_transpose`

use std::time::Instant;

use stratus::hw::transpose_buffer::TransposableBuffer;
use stratus::nn::testutil::{randi, Lcg};

fn main() {
    println!("=== transposable weight buffer ablation ===");
    println!("{:<14} {:>10} {:>12} {:>12} {:>9}", "kernel set",
             "words", "BP circulant", "BP naive", "speedup");
    let mut rng = Lcg::new(1);
    for (nof, nif) in [(16, 16), (32, 32), (64, 64), (128, 128),
                       (256, 256)] {
        let w = randi(&mut rng, &[nof, nif, 3, 3], 500);
        let tb = TransposableBuffer::store(&w);
        println!("{:<14} {:>10} {:>12} {:>12} {:>8}x",
                 format!("{nof}x{nif}x3x3"), tb.storage_words(),
                 tb.bp_stream_cycles(), tb.naive_bp_stream_cycles(),
                 tb.naive_bp_stream_cycles() / tb.bp_stream_cycles());
    }
    println!("\n(the circulant layout reads a full transpose row per \
              cycle with zero bank conflicts and zero duplicated \
              storage — Fig. 5)");

    // host-side wall-clock of the functional model (store + full FP +
    // full BP traversal), for the perf log
    let w = randi(&mut rng, &[256, 256, 3, 3], 500);
    let t0 = Instant::now();
    let mut tb = TransposableBuffer::store(&w);
    let t_store = t0.elapsed();
    let t1 = Instant::now();
    let mut acc = 0i64;
    for of in 0..256 {
        for r in 0..256 {
            acc += i64::from(tb.read_normal(of, r)[0]);
        }
    }
    let t_fp = t1.elapsed();
    let t2 = Instant::now();
    for r in 0..256 {
        for b in tb.read_transpose_row(r) {
            acc += i64::from(b[0]);
        }
    }
    let t_bp = t2.elapsed();
    println!("\nhost wall-clock (256x256x3x3): store {:.2} ms, FP stream \
              {:.2} ms, BP stream {:.2} ms (checksum {acc})",
             t_store.as_secs_f64() * 1e3, t_fp.as_secs_f64() * 1e3,
             t_bp.as_secs_f64() * 1e3);
}
