//! Bench: regenerate Fig. 10 (on-chip buffer usage breakdown of the
//! CIFAR-10 4X design).  `cargo bench --bench fig10`

use stratus::config::{DesignVars, Network};
use stratus::hw::bram::BufferPlan;
use stratus::metrics::fig10;

fn main() {
    println!("=== Fig. 10 (reproduced): 4X buffer usage ===");
    println!("{}", fig10());

    // the paper's qualitative claims: the weight buffer (sized by the
    // largest layer, not tiled) dominates; index/mask buffers are tiny
    let plan = BufferPlan::plan(&Network::cifar(4),
                                &DesignVars::for_scale(4));
    println!("per-buffer detail:");
    for b in &plan.buffers {
        println!("  {:<12} {:>10} bits ({} words x {}b{})",
                 b.name, b.bits(), b.words, b.bits_per_word,
                 if b.double { ", double-buffered" } else { "" });
    }
    println!("total: {:.2} Mbit structural ({} M20K blocks)",
             plan.total_mbits(), plan.total_m20k());

    let by_group = plan.bits_by_group();
    let weight_bits = by_group
        .iter()
        .find(|(g, _)| format!("{g:?}") == "Weight")
        .map(|(_, b)| *b)
        .unwrap_or(0);
    println!("weight buffer share: {:.1}% (paper: weight buffer sized \
              by the largest layer dominates)",
             weight_bits as f64 / plan.total_bits() as f64 * 100.0);
}
