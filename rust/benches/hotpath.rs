//! Hot-path performance bench (§Perf in EXPERIMENTS.md): host-side
//! throughput of the three coordinator backends on the real 1X workload,
//! plus PJRT dispatch overhead.  Requires `make artifacts` for the PJRT
//! backends (golden-only otherwise).  `cargo bench --bench hotpath`

use std::path::Path;
use std::time::Instant;

use stratus::coordinator::Backend;
use stratus::data::Synthetic;
use stratus::session::{Session, Spec};

fn bench_backend(backend: Backend, artifacts: Option<&Path>, n: usize)
                 -> Option<(f64, f64)> {
    let mut b = Spec::builder()
        .preset("1x")
        .backend(backend)
        .batch(n)
        .lr(0.002)
        .momentum(0.9);
    if let Some(dir) = artifacts {
        b = b.artifacts(dir);
    }
    let mut t = Session::new(b.build().ok()?).ok()?.trainer().ok()?;
    let data = Synthetic::cifar_like(99);
    let batch = data.batch(0, n);
    // warmup (compiles artifacts on first use)
    t.train_image(&batch[0]).ok()?;
    let t0 = Instant::now();
    for s in &batch {
        t.train_image(s).ok()?;
    }
    let dt = t0.elapsed().as_secs_f64();
    Some((n as f64 / dt, dt / n as f64 * 1e3))
}

fn main() {
    let artifacts = Path::new("artifacts");
    let have = artifacts.join("manifest.json").exists();
    let n = 16;
    println!("=== coordinator hot path (1X, {n} images) ===");
    println!("{:<10} {:>12} {:>14}", "backend", "images/s", "ms/image");
    if let Some((ips, ms)) = bench_backend(Backend::Golden, None, n) {
        println!("{:<10} {:>12.2} {:>14.2}", "golden", ips, ms);
    }
    if have {
        for (name, b) in [("perop", Backend::PerOp),
                          ("fused", Backend::Fused)] {
            if let Some((ips, ms)) =
                bench_backend(b, Some(artifacts), n)
            {
                println!("{:<10} {:>12.2} {:>14.2}", name, ips, ms);
            }
        }
    } else {
        println!("(PJRT backends skipped: run `make artifacts`)");
    }
    println!("\nsimulated accelerator reference: ~0.36 ms/image (1X, \
              240 MHz) — host numerics are for validation, not on the \
              modeled FPGA's critical path");
}
