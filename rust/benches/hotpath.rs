//! Per-kernel hot-path bench (ISSUE 7 tentpole; pool rows from ISSUE
//! 9): tiled vs reference throughput of the golden-model kernels on
//! the 1X workload shapes — conv FP/BP/WU across the six conv
//! geometries, the FC triplet, the BN per-pixel passes, and the
//! row-blocked maxpool FP / upsample BP pair across the three 1X pool
//! geometries.  One rep of a kernel equals one image's worth of that
//! kernel across the whole network, so every series is an images/s
//! figure comparable with the engine benches.
//!
//! `cargo bench --bench hotpath [-- --smoke]`: smoke mode (also
//! `BENCH_SMOKE=1`) shortens the rep counts for CI.  Writes
//! `BENCH_hotpath.json` with per-kernel `<k>_ips` / `<k>_ref_ips` /
//! `<k>_speedup` extras, and gates the composite plus each per-kernel
//! series against `benches/baseline.json` (metrics::bench::
//! finish_gated) — CI archives the record SHA-named for the perf
//! trajectory.  The reference side runs the scalar oracles in
//! `stratus::nn::reference` exactly as the pre-tiling golden model did
//! (including one `transpose_flip` per BP call, which the tiled side
//! amortizes through the Scratch flip cache).

use std::hint::black_box;
use std::time::Instant;

use stratus::fixed::{FA, FW};
use stratus::metrics::bench::{finish_gated, smoke_mode, BenchRecord};
use stratus::nn::tensor::Tensor;
use stratus::nn::testutil::{randi, Lcg};
use stratus::nn::{bn, conv, fc, pool, reference, Scratch};

/// The 1X preset's conv stack: (cin, cout, spatial), k = 3, pad = 1.
const CONVS: [(usize, usize, usize); 6] = [
    (3, 16, 32),
    (16, 16, 32),
    (16, 32, 16),
    (32, 32, 16),
    (32, 64, 8),
    (64, 64, 8),
];

/// One conv layer's bench inputs.
struct ConvCase {
    x: Tensor,
    w: Tensor,
    b: Vec<i32>,
    /// Output/incoming gradient plane (cout, h, h), pool-sparse.
    g: Tensor,
    /// Output-shaped activation (cout, h, h) — the BN layer's input.
    xo: Tensor,
    /// Flip-cache key for the tiled BP path.
    key: String,
}

fn conv_cases(rng: &mut Lcg) -> Vec<ConvCase> {
    CONVS
        .iter()
        .enumerate()
        .map(|(i, &(cin, cout, h))| {
            let mut g = randi(rng, &[cout, h, h], 900);
            // maxpool upsampling leaves 3/4 of gradient pixels zero;
            // give the WU/BP zero-skip its realistic duty cycle
            for v in g.data_mut() {
                if rng.below(4) != 0 {
                    *v = 0;
                }
            }
            ConvCase {
                x: randi(rng, &[cin, h, h], 900),
                w: randi(rng, &[cout, cin, 3, 3], 150),
                b: (0..cout).map(|_| rng.int_pm(1 << 16)).collect(),
                g,
                xo: randi(rng, &[cout, h, h], 900),
                key: format!("conv{i}"),
            }
        })
        .collect()
}

/// Seconds per rep of `f`, with the checksum kept live.
fn time_per_rep<F: FnMut() -> i64>(reps: usize, mut f: F) -> f64 {
    let mut sink = 0i64;
    let t0 = Instant::now();
    for _ in 0..reps {
        sink = sink.wrapping_add(f());
    }
    let dt = t0.elapsed().as_secs_f64();
    black_box(sink);
    dt / reps as f64
}

fn sum_t(t: &Tensor) -> i64 {
    t.data().iter().map(|&v| i64::from(v)).sum()
}

fn sum_v(v: &[i32]) -> i64 {
    v.iter().map(|&x| i64::from(x)).sum()
}

struct Kernel {
    name: &'static str,
    ips: f64,
    ref_ips: f64,
}

fn main() {
    let smoke = smoke_mode();
    // rep counts sized so even the smoke run measures >> timer
    // granularity (a conv rep is ~10M MACs)
    let (conv_reps, fc_reps, bn_reps) =
        if smoke { (3, 300, 5) } else { (20, 3000, 40) };

    let mut rng = Lcg::new(1234);
    let cases = conv_cases(&mut rng);
    let mut scratch = Scratch::new();
    let mut kernels: Vec<Kernel> = Vec::new();

    // --- conv FP -----------------------------------------------------
    let ips = 1.0
        / time_per_rep(conv_reps, || {
            let mut s = 0i64;
            for c in &cases {
                s += sum_t(&conv::conv_fp_std_s(
                    &c.x, &c.w, &c.b, true, &mut scratch,
                ));
            }
            s
        });
    let ref_ips = 1.0
        / time_per_rep(conv_reps, || {
            let mut s = 0i64;
            for c in &cases {
                s += sum_t(&reference::conv_fp_std(
                    &c.x, &c.w, &c.b, true,
                ));
            }
            s
        });
    kernels.push(Kernel { name: "conv_fp", ips, ref_ips });

    // --- conv BP (tiled side amortizes the flip via the cache) -------
    let ips = 1.0
        / time_per_rep(conv_reps, || {
            let mut s = 0i64;
            for c in &cases {
                s += sum_t(&conv::conv_bp_s(
                    &c.g, &c.w, &c.key, 1, &mut scratch,
                ));
            }
            s
        });
    let ref_ips = 1.0
        / time_per_rep(conv_reps, || {
            let mut s = 0i64;
            for c in &cases {
                s += sum_t(&reference::conv_bp(&c.g, &c.w, 1));
            }
            s
        });
    kernels.push(Kernel { name: "conv_bp", ips, ref_ips });

    // --- conv WU -----------------------------------------------------
    let ips = 1.0
        / time_per_rep(conv_reps, || {
            let mut s = 0i64;
            for c in &cases {
                let (dw, db) =
                    conv::conv_wu_s(&c.x, &c.g, 1, &mut scratch);
                s += sum_t(&dw) + sum_v(&db);
            }
            s
        });
    let ref_ips = 1.0
        / time_per_rep(conv_reps, || {
            let mut s = 0i64;
            for c in &cases {
                let (dw, db) = reference::conv_wu(&c.x, &c.g, 1);
                s += sum_t(&dw) + sum_v(&db);
            }
            s
        });
    kernels.push(Kernel { name: "conv_wu", ips, ref_ips });

    // --- fc (fp + bp + wu, the classifier head 1024 -> 10) -----------
    let fx: Vec<i32> = (0..1024).map(|_| rng.int_pm(900)).collect();
    let fw = randi(&mut rng, &[10, 1024], 150);
    let fb: Vec<i32> = (0..10).map(|_| rng.int_pm(1 << 16)).collect();
    let fg: Vec<i32> = (0..10).map(|_| rng.int_pm(900)).collect();
    let ips = 1.0
        / time_per_rep(fc_reps, || {
            let y = fc::fc_fp(&fx, &fw, &fb);
            let gx = fc::fc_bp(&fg, &fw);
            let (dw, db) = fc::fc_wu(&fg, &fx);
            sum_v(&y) + sum_v(&gx) + sum_t(&dw) + sum_v(&db)
        });
    let ref_ips = 1.0
        / time_per_rep(fc_reps, || {
            let y = reference::fc_fp(&fx, &fw, &fb);
            let gx = reference::fc_bp(&fg, &fw);
            let (dw, db) = reference::fc_wu(&fg, &fx);
            sum_v(&y) + sum_v(&gx) + sum_t(&dw) + sum_v(&db)
        });
    kernels.push(Kernel { name: "fc", ips, ref_ips });

    // --- bn (stats + forward + backward passes; channel-contiguous
    // already, benched for the composite and its own floor) -----------
    let bn_params: Vec<_> = CONVS
        .iter()
        .map(|&(_, cout, _)| {
            (
                Tensor::from_vec(&[cout], vec![1 << FW; cout]),
                Tensor::zeros(&[cout]),
                Tensor::zeros(&[cout]),
                Tensor::from_vec(&[cout], vec![1 << (2 * FA); cout]),
            )
        })
        .collect();
    let bn_time = time_per_rep(bn_reps, || {
        let mut s = 0i64;
        for (c, (gamma, beta, rm, rv)) in
            cases.iter().zip(&bn_params)
        {
            let (m, q) = bn::image_stats(&c.xo);
            let y = bn::forward_affine(&c.xo, gamma, beta, rm, rv, true);
            let gx = bn::backward_input(&c.g, gamma, rv);
            let (dg, db) = bn::backward_params(&c.g, &c.xo, rm, rv);
            s += sum_t(&m) + sum_t(&q) + sum_t(&y) + sum_t(&gx)
                + sum_t(&dg) + sum_v(&db);
        }
        s
    });
    let bn_ips = 1.0 / bn_time;
    kernels.push(Kernel { name: "bn", ips: bn_ips, ref_ips: bn_ips });

    // --- pool (row-blocked maxpool FP + upsample BP vs the scalar
    // oracles, across the 1X pool geometries) -------------------------
    let pool_reps = if smoke { 50 } else { 500 };
    let pool_cases: Vec<_> = [(16usize, 32usize), (32, 16), (64, 8)]
        .iter()
        .map(|&(c, h)| {
            let x = randi(&mut rng, &[c, h, h], 900);
            let (_, idx) = pool::maxpool(&x, 2);
            let g = randi(&mut rng, &[c, h / 2, h / 2], 900);
            let mask = pool::relu_mask(&x);
            (x, idx, g, mask)
        })
        .collect();
    let ips = 1.0
        / time_per_rep(pool_reps, || {
            let mut s = 0i64;
            for (x, _, _, _) in &pool_cases {
                let (p, idx) = pool::maxpool(x, 2);
                s += sum_t(&p) + sum_t(&idx);
            }
            s
        });
    let ref_ips = 1.0
        / time_per_rep(pool_reps, || {
            let mut s = 0i64;
            for (x, _, _, _) in &pool_cases {
                let (p, idx) = reference::maxpool(x, 2);
                s += sum_t(&p) + sum_t(&idx);
            }
            s
        });
    kernels.push(Kernel { name: "pool_fp", ips, ref_ips });
    let ips = 1.0
        / time_per_rep(pool_reps, || {
            let mut s = 0i64;
            for (_, idx, g, mask) in &pool_cases {
                s += sum_t(&pool::upsample_scale(g, idx, mask, 2));
            }
            s
        });
    let ref_ips = 1.0
        / time_per_rep(pool_reps, || {
            let mut s = 0i64;
            for (_, idx, g, mask) in &pool_cases {
                s += sum_t(&reference::upsample_scale(g, idx, mask, 2));
            }
            s
        });
    kernels.push(Kernel { name: "pool_bp", ips, ref_ips });

    // --- report + record ---------------------------------------------
    println!("=== per-kernel hot path (1X shapes{}) ===",
             if smoke { ", smoke" } else { "" });
    println!("{:<10} {:>12} {:>12} {:>9}", "kernel", "images/s",
             "ref img/s", "speedup");
    let mut rec = BenchRecord::new(
        "hotpath",
        1.0 / kernels.iter().map(|k| 1.0 / k.ips).sum::<f64>(),
        smoke,
    );
    let mut gates: Vec<(String, f64)> = Vec::new();
    for k in &kernels {
        let speedup = k.ips / k.ref_ips;
        println!("{:<10} {:>12.1} {:>12.1} {:>8.2}x", k.name, k.ips,
                 k.ref_ips, speedup);
        rec.push(&format!("{}_ips", k.name), k.ips);
        rec.push(&format!("{}_ref_ips", k.name), k.ref_ips);
        rec.push(&format!("{}_speedup", k.name), speedup);
        gates.push((format!("hotpath_{}", k.name), k.ips));
    }
    println!("composite      : {:.1} images/s (harmonic over the {} \
              kernel groups)", rec.images_per_second, kernels.len());
    let gate_refs: Vec<(&str, f64)> =
        gates.iter().map(|(n, v)| (n.as_str(), *v)).collect();
    std::process::exit(finish_gated(&rec, &gate_refs));
}
