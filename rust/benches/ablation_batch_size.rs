//! Ablation bench (§IV-B, citing Masters & Luschi [25]): "stable and
//! reliable training can also be achieved with smaller batch sizes as it
//! provides more up-to-date gradient calculations" — and the FPGA's
//! throughput does NOT depend on batch size (images are processed
//! sequentially), unlike the GPU.
//!
//! Trains the same image budget at different batch sizes through the
//! golden backend and reports accuracy + simulated epoch latency.
//! `cargo bench --bench ablation_batch_size`

use stratus::config::Network;
use stratus::data::Synthetic;
use stratus::gpu_model::titan_xp;
use stratus::session::{Session, Spec};

const NET_CFG: &str = "input 3 16 16\nconv c1 8 k3 s1 p1 relu\n\
                       conv c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                       loss hinge";

fn main() {
    let data = Synthetic::new(10, (3, 16, 16), 11, 0.4);
    let train = data.batch(0, 96);
    let test = data.batch(10_000, 100);
    let budget_epochs = 4;

    println!("=== batch-size ablation (same image budget, {} epochs) ===",
             budget_epochs);
    println!("{:>5} {:>9} {:>10} {:>10}", "BS", "updates", "test acc",
             "mean loss");
    for bs in [2usize, 8, 32] {
        let spec = Spec::builder()
            .net_inline(NET_CFG)
            .batch(bs)
            .lr(0.01)
            .momentum(0.9)
            .build()
            .unwrap();
        let mut t = Session::new(spec).unwrap().trainer().unwrap();
        let mut loss = 0.0;
        let mut n = 0;
        for _ in 0..budget_epochs {
            for chunk in train.chunks(bs) {
                loss += t.train_batch(chunk).unwrap();
                n += 1;
            }
        }
        let acc = t.evaluate(&test).unwrap();
        println!("{:>5} {:>9} {:>9.1}% {:>10.1}", bs, t.metrics.batches,
                 acc * 100.0, loss / n as f64);
    }

    // throughput vs batch size: FPGA flat, GPU strongly batch-dependent
    println!("\n=== throughput vs batch size (1X) ===");
    println!("{:>5} {:>12} {:>12}", "BS", "FPGA GOPS", "GPU GOPS");
    let cifar = Network::cifar(1);
    for bs in [1usize, 10, 40] {
        let paper = Session::new(
            Spec::builder().preset("1x").batch(bs).build().unwrap(),
        )
        .unwrap();
        let fpga = paper.simulate().unwrap().gops();
        let gpu = titan_xp(&cifar, bs).gops;
        println!("{:>5} {:>12.0} {:>12.1}", bs, fpga, gpu);
    }
    println!("\n(paper: \"our performance remains the same for different \
              batch sizes as the images in a batch are processed \
              sequentially\")");
}
