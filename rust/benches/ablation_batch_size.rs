//! Ablation bench (§IV-B, citing Masters & Luschi [25]): "stable and
//! reliable training can also be achieved with smaller batch sizes as it
//! provides more up-to-date gradient calculations" — and the FPGA's
//! throughput does NOT depend on batch size (images are processed
//! sequentially), unlike the GPU.
//!
//! Trains the same image budget at different batch sizes through the
//! golden backend and reports accuracy + simulated epoch latency.
//! `cargo bench --bench ablation_batch_size`

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::coordinator::{Backend, Trainer};
use stratus::data::Synthetic;
use stratus::gpu_model::titan_xp;
use stratus::sim::simulate;

fn main() {
    let net = Network::parse(
        "input 3 16 16\nconv c1 8 k3 s1 p1 relu\nconv c2 8 k3 s1 p1 \
         relu\npool p1 2\nfc fc 10\nloss hinge",
    )
    .unwrap();
    let dv = DesignVars::default();
    let data = Synthetic::new(10, (3, 16, 16), 11, 0.4);
    let train = data.batch(0, 96);
    let test = data.batch(10_000, 100);
    let budget_epochs = 4;

    println!("=== batch-size ablation (same image budget, {} epochs) ===",
             budget_epochs);
    println!("{:>5} {:>9} {:>10} {:>10}", "BS", "updates", "test acc",
             "mean loss");
    for bs in [2usize, 8, 32] {
        let mut t = Trainer::new(&net, &dv, bs, 0.01, 0.9,
                                 Backend::Golden, None)
            .unwrap();
        let mut loss = 0.0;
        let mut n = 0;
        for _ in 0..budget_epochs {
            for chunk in train.chunks(bs) {
                loss += t.train_batch(chunk).unwrap();
                n += 1;
            }
        }
        let acc = t.evaluate(&test).unwrap();
        println!("{:>5} {:>9} {:>9.1}% {:>10.1}", bs, t.metrics.batches,
                 acc * 100.0, loss / n as f64);
    }

    // throughput vs batch size: FPGA flat, GPU strongly batch-dependent
    println!("\n=== throughput vs batch size (1X) ===");
    println!("{:>5} {:>12} {:>12}", "BS", "FPGA GOPS", "GPU GOPS");
    let cifar = Network::cifar(1);
    let acc1 = RtlCompiler::default()
        .compile(&cifar, &DesignVars::for_scale(1))
        .unwrap();
    for bs in [1usize, 10, 40] {
        let fpga = simulate(&acc1, bs).gops();
        let gpu = titan_xp(&cifar, bs).gops;
        println!("{:>5} {:>12.0} {:>12.1}", bs, fpga, gpu);
    }
    println!("\n(paper: \"our performance remains the same for different \
              batch sizes as the images in a batch are processed \
              sequentially\")");
}
