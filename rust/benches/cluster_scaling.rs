//! Cluster scaling bench (ISSUE 2 tentpole): host-side images/sec of
//! the data-parallel cluster engine at 1/2/4/8 accelerator instances —
//! with a bit-identity check against single-instance training — plus
//! the hardware model's cluster projection including the ring
//! all-reduce communication.
//!
//! `cargo bench --bench cluster_scaling [-- --smoke]`: smoke mode (also
//! `BENCH_SMOKE=1`) runs one batch per instance count for CI.  The
//! bench writes `BENCH_cluster_scaling.json` and exits nonzero when the
//! headline `images_per_second` regresses more than 30% below
//! `benches/baseline.json`, or on a bit-identity mismatch
//! (metrics::bench::ScalingBench).

use std::time::Instant;

use stratus::data::Synthetic;
use stratus::metrics::bench::{smoke_mode, ScalingBench};
use stratus::metrics::cluster_scaling;
use stratus::session::{Session, Spec};

const NET_CFG: &str = "input 3 16 16\nconv c1 8 k3 s1 p1 relu\n\
                       conv c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                       loss hinge";

fn main() {
    let smoke = smoke_mode();
    let data = Synthetic::new(10, (3, 16, 16), 23, 0.3);
    let batch_size = 32;
    let batches = if smoke { 1 } else { 4 };
    let train = data.batch(0, batch_size * batches);

    println!("=== cluster engine: host throughput vs instances{} ===",
             if smoke { " (smoke)" } else { "" });
    println!("{:<10} {:>10} {:>12} {:>9} {:>15}", "instances",
             "images/s", "ms/image", "speedup", "vs 1 instance");
    let mut bench = ScalingBench::new("cluster_scaling", smoke);
    for instances in [1usize, 2, 4, 8] {
        let spec = Spec::builder()
            .net_inline(NET_CFG)
            .batch(batch_size)
            .lr(0.02)
            .momentum(0.9)
            .accelerators(instances)
            .build()
            .unwrap();
        let mut t = Session::new(spec).unwrap().trainer().unwrap();
        // warmup batch (identical across instance counts, so final
        // params stay comparable); the spec compiles the cluster
        // design up front, so the all-reduce cost cache is already
        // warm — the warmup keeps the measurement protocol symmetric
        // with the engine bench
        t.train_batch(&train[..batch_size]).unwrap();
        let t0 = Instant::now();
        for chunk in train.chunks(batch_size) {
            t.train_batch(chunk).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let n = train.len() as f64;
        let ips = n / dt;
        let (speedup, verdict) = bench.observe(ips, t.flat_params());
        println!("{:<10} {:>10.1} {:>12.3} {:>8.2}x {:>15}", instances,
                 ips, dt / n * 1e3, speedup, verdict);
    }

    println!("\n=== hardware model: cluster projection with ring \
              all-reduce (1X @ BS 40) ===");
    println!("{}", cluster_scaling(1, 40, &[1, 2, 4, 8, 16]));

    std::process::exit(bench.finish(&[
        ("batch_size", batch_size as f64),
        ("batches", batches as f64),
    ]));
}
