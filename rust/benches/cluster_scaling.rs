//! Cluster scaling bench (ISSUE 2 tentpole; topology sweep from ISSUE
//! 8): host-side images/sec of the data-parallel cluster engine across
//! instance counts *and* collective topologies — every configuration
//! bit-identity-checked against single-instance training — plus the
//! hardware model's large-N projection of ring vs hierarchical
//! all-reduce (N = 4/16/64, where host training would be pointlessly
//! slow but the cycle model is free).
//!
//! `cargo bench --bench cluster_scaling [-- --smoke]`: smoke mode (also
//! `BENCH_SMOKE=1`) runs one batch per configuration for CI.  The bench
//! writes `BENCH_cluster_scaling.json` and exits nonzero when the
//! headline `images_per_second` or the `cluster_hier` series regresses
//! more than 30% below `benches/baseline.json`, or on a bit-identity
//! mismatch (metrics::bench::ScalingBench).

use std::time::Instant;

use stratus::config::Topology;
use stratus::data::Synthetic;
use stratus::metrics::bench::{smoke_mode, ScalingBench};
use stratus::metrics::topology_scaling;
use stratus::session::{Session, Spec};

const NET_CFG: &str = "input 3 16 16\nconv c1 8 k3 s1 p1 relu\n\
                       conv c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                       loss hinge";

fn main() {
    let smoke = smoke_mode();
    let data = Synthetic::new(10, (3, 16, 16), 23, 0.3);
    let batch_size = 32;
    let batches = if smoke { 1 } else { 4 };
    let train = data.batch(0, batch_size * batches);

    println!("=== cluster engine: host throughput vs instances and \
              topology{} ===",
             if smoke { " (smoke)" } else { "" });
    println!("{:<10} {:<9} {:>10} {:>12} {:>9} {:>15}", "instances",
             "topology", "images/s", "ms/image", "speedup",
             "vs 1 instance");
    let mut bench = ScalingBench::new("cluster_scaling", smoke);
    let mut hier_ips = 0.0f64;
    // the ring sweep reproduces the historical bench; the hier runs
    // re-merge the same counts through the grouped collective (4 = 2x2
    // groups, 8 = the compiler's best divisor) and must stay
    // bit-identical to the 1-instance reference
    let sweep = [(1usize, Topology::Ring), (2, Topology::Ring),
                 (4, Topology::Ring), (8, Topology::Ring),
                 (4, Topology::Hier), (8, Topology::Hier)];
    for (instances, topology) in sweep {
        let spec = Spec::builder()
            .net_inline(NET_CFG)
            .batch(batch_size)
            .lr(0.02)
            .momentum(0.9)
            .accelerators(instances)
            .topology(topology)
            .build()
            .unwrap();
        let mut t = Session::new(spec).unwrap().trainer().unwrap();
        // warmup batch (identical across configurations, so final
        // params stay comparable); the spec compiles the cluster
        // design up front, so the all-reduce cost cache is already
        // warm — the warmup keeps the measurement protocol symmetric
        // with the engine bench
        t.train_batch(&train[..batch_size]).unwrap();
        let t0 = Instant::now();
        for chunk in train.chunks(batch_size) {
            t.train_batch(chunk).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let n = train.len() as f64;
        let ips = n / dt;
        if topology == Topology::Hier {
            hier_ips = hier_ips.max(ips);
        }
        let (speedup, verdict) = bench.observe(ips, t.flat_params());
        println!("{:<10} {:<9} {:>10.1} {:>12.3} {:>8.2}x {:>15}",
                 instances, topology.to_string(), ips, dt / n * 1e3,
                 speedup, verdict);
    }

    println!("\n=== hardware model: ring vs hierarchical all-reduce \
              (1X @ BS 40, N = 4/16/64) ===");
    println!("{}", topology_scaling(1, 40, &[4, 16, 64]));

    std::process::exit(bench.finish_with(
        &[("batch_size", batch_size as f64),
          ("batches", batches as f64),
          ("images_per_second_hier", hier_ips)],
        &[("cluster_hier", hier_ips)],
    ));
}
