//! Cluster scaling bench (ISSUE 2 tentpole; topology sweep from ISSUE
//! 8; bucketed overlap from ISSUE 9): host-side images/sec of the
//! data-parallel cluster engine across instance counts, collective
//! topologies, *and* the pipelined bucketed merge — every
//! configuration bit-identity-checked against single-instance training
//! — plus the hardware model's large-N projections of ring vs
//! hierarchical all-reduce and of hidden vs exposed comm under the
//! bucketed overlap (N = 4/16/64, where host training would be
//! pointlessly slow but the cycle model is free).
//!
//! `cargo bench --bench cluster_scaling [-- --smoke]`: smoke mode (also
//! `BENCH_SMOKE=1`) runs one batch per configuration for CI.  The bench
//! writes `BENCH_cluster_scaling.json` and exits nonzero when the
//! headline `images_per_second`, the `cluster_hier` series, or the
//! `cluster_overlap` series regresses more than 30% below
//! `benches/baseline.json`, or on a bit-identity mismatch
//! (metrics::bench::ScalingBench).

use std::time::Instant;

use stratus::config::Topology;
use stratus::data::Synthetic;
use stratus::metrics::bench::{smoke_mode, ScalingBench};
use stratus::metrics::{overlap_scaling, topology_scaling};
use stratus::session::{Session, Spec};

const NET_CFG: &str = "input 3 16 16\nconv c1 8 k3 s1 p1 relu\n\
                       conv c2 8 k3 s1 p1 relu\npool p1 2\nfc fc 10\n\
                       loss hinge";

fn main() {
    let smoke = smoke_mode();
    let data = Synthetic::new(10, (3, 16, 16), 23, 0.3);
    let batch_size = 32;
    let batches = if smoke { 1 } else { 4 };
    let train = data.batch(0, batch_size * batches);

    println!("=== cluster engine: host throughput vs instances, \
              topology, and bucketed overlap{} ===",
             if smoke { " (smoke)" } else { "" });
    println!("{:<10} {:<12} {:>10} {:>12} {:>9} {:>15}", "instances",
             "merge", "images/s", "ms/image", "speedup",
             "vs 1 instance");
    let mut bench = ScalingBench::new("cluster_scaling", smoke);
    let mut hier_ips = 0.0f64;
    let mut overlap_ips = 0.0f64;
    // the ring sweep reproduces the historical bench; the hier runs
    // re-merge the same counts through the grouped collective; the
    // bucket-kwords-1 runs walk the same merge as per-layer buckets
    // launched in reverse-BP order (the tiny net's ~6.4K-word gradient
    // splits at a 1 KiW cap).  Every configuration must stay
    // bit-identical to the 1-instance reference.
    let sweep = [(1usize, Topology::Ring, 0usize), (2, Topology::Ring, 0),
                 (4, Topology::Ring, 0), (8, Topology::Ring, 0),
                 (4, Topology::Hier, 0), (8, Topology::Hier, 0),
                 (4, Topology::Ring, 1), (8, Topology::Ring, 1),
                 (8, Topology::Hier, 1)];
    for (instances, topology, kwords) in sweep {
        let mut b = Spec::builder()
            .net_inline(NET_CFG)
            .batch(batch_size)
            .lr(0.02)
            .momentum(0.9)
            .accelerators(instances)
            .topology(topology);
        if kwords > 0 {
            b = b.bucket_kwords(kwords);
        }
        let spec = b.build().unwrap();
        let mut t = Session::new(spec).unwrap().trainer().unwrap();
        // warmup batch (identical across configurations, so final
        // params stay comparable); it also populates the persistent
        // worker pool, so the measured batches reuse shard scratch and
        // forks instead of allocating
        t.train_batch(&train[..batch_size]).unwrap();
        let t0 = Instant::now();
        for chunk in train.chunks(batch_size) {
            t.train_batch(chunk).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let n = train.len() as f64;
        let ips = n / dt;
        if kwords > 0 {
            overlap_ips = overlap_ips.max(ips);
        } else if topology == Topology::Hier {
            hier_ips = hier_ips.max(ips);
        }
        let merge = format!("{}{}", topology,
                            if kwords > 0 { "+ovl" } else { "" });
        let (speedup, verdict) = bench.observe(ips, t.flat_params());
        println!("{:<10} {:<12} {:>10.1} {:>12.3} {:>8.2}x {:>15}",
                 instances, merge, ips, dt / n * 1e3, speedup,
                 verdict);
    }

    println!("\n=== hardware model: ring vs hierarchical all-reduce \
              (1X @ BS 40, N = 4/16/64) ===");
    println!("{}", topology_scaling(1, 40, &[4, 16, 64]));

    println!("\n=== hardware model: bucketed overlap, hidden vs \
              exposed comm (1X @ BS 64, N = 4/16/64) ===");
    println!("{}", overlap_scaling(1, 64, &[4, 16, 64]));

    std::process::exit(bench.finish_with(
        &[("batch_size", batch_size as f64),
          ("batches", batches as f64),
          ("images_per_second_hier", hier_ips),
          ("images_per_second_overlap", overlap_ips)],
        &[("cluster_hier", hier_ips),
          ("cluster_overlap", overlap_ips)],
    ));
}
