//! Bench: regenerate Table III (FPGA vs Titan XP throughput and
//! efficiency at batch sizes 1 and 40).  `cargo bench --bench table3`

use std::time::Instant;

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::gpu_model::titan_xp;
use stratus::metrics::table3;
use stratus::sim::simulate;

// paper Table III: (net, gpu_b1, gpu_b40, fpga, eff_b1, eff_b40, eff_fpga)
const PAPER: &[(&str, f64, f64, f64, f64, f64, f64)] = &[
    ("CIFAR-10 1X", 45.67, 551.87, 163.0, 0.50, 3.68, 7.90),
    ("CIFAR-10 2X", 128.84, 1337.98, 282.0, 1.30, 8.26, 8.59),
    ("CIFAR-10 4X", 331.41, 2353.79, 479.0, 2.91, 13.45, 9.49),
];

fn main() {
    let t0 = Instant::now();
    let ours = table3();
    println!("=== Table III (reproduced) ===");
    println!("{ours}");
    println!("=== Table III (paper) ===");
    for (n, g1, g40, f, e1, e40, ef) in PAPER {
        println!("{n}: GPU {g1}/{g40} GOPS (B1/B40), FPGA {f} GOPS; \
                  eff GPU {e1}/{e40}, FPGA {ef} GOPS/W");
    }

    // the paper's crossover claim: FPGA beats GPU efficiency at B1 for
    // every net; at B40 the 4X model loses to the GPU
    println!("\n=== crossover check ===");
    for scale in [1usize, 2, 4] {
        let net = Network::cifar(scale);
        let acc = RtlCompiler::default()
            .compile(&net, &DesignVars::for_scale(scale))
            .unwrap();
        let fpga_eff =
            simulate(&acc, 40).gops() / acc.power.total();
        let gpu_b1 = titan_xp(&net, 1).efficiency();
        let gpu_b40 = titan_xp(&net, 40).efficiency();
        println!(
            "{}X: FPGA {fpga_eff:.2} GOPS/W vs GPU B1 {gpu_b1:.2} \
             (FPGA {}), vs GPU B40 {gpu_b40:.2} (FPGA {})",
            scale,
            if fpga_eff > gpu_b1 { "WINS" } else { "loses" },
            if fpga_eff > gpu_b40 { "wins" } else { "LOSES" },
        );
    }
    println!("\nregenerated in {:.1} ms",
             t0.elapsed().as_secs_f64() * 1e3);
}
