//! Ablation bench (Fig. 8 / §III-F): MAC load balancing during weight-
//! gradient convolutions.  The paper reports 4X lower WU logic latency
//! with the load-balance unit for Pox=Poy=8, k=3.
//! `cargo bench --bench ablation_load_balance`

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::hw::mac_array::{wu_balance_factor, wu_cycles};
use stratus::sim::simulate;

fn main() {
    println!("=== MAC load-balance ablation ===");
    println!("{:<6} {:>14} {:>14} {:>8}", "net",
             "WU logic (on)", "WU logic (off)", "speedup");
    for scale in [1usize, 2, 4] {
        let net = Network::cifar(scale);
        let mut dv = DesignVars::for_scale(scale);
        let on = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        dv.load_balance = false;
        let off = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        println!("{:<6} {:>14} {:>14} {:>7.2}x", format!("{scale}X"),
                 on.wu.logic_cycles, off.wu.logic_cycles,
                 off.wu.logic_cycles as f64 / on.wu.logic_cycles as f64);
    }
    let dv = DesignVars::for_scale(1);
    println!("\nbalance factor for Pox=Poy=8, k=3: {} (paper Fig. 8: 4 \
              kernel gradients in parallel -> 4X)",
             wu_balance_factor(&dv, 3));

    // per-layer view for the paper's Fig. 8 example (16 maps, 8x8)
    let c = wu_cycles(&dv, 16, 16, 8, 8, 3);
    let mut dv_off = dv.clone();
    dv_off.load_balance = false;
    let c_off = wu_cycles(&dv_off, 16, 16, 8, 8, 3);
    println!("Fig. 8 example (Nof=16, 8x8): {} -> {} cycles ({}x)",
             c_off.cycles, c.cycles, c_off.cycles / c.cycles);

    // end-to-end effect on the iteration
    let net = Network::cifar(4);
    let mut dv4 = DesignVars::for_scale(4);
    let on = simulate(
        &RtlCompiler::default().compile(&net, &dv4).unwrap(), 40);
    dv4.load_balance = false;
    let off = simulate(
        &RtlCompiler::default().compile(&net, &dv4).unwrap(), 40);
    println!("\n4X end-to-end: {:.3} -> {:.3} ms/image ({:.1}% faster \
              with load balancing)",
             off.seconds_per_image() * 1e3, on.seconds_per_image() * 1e3,
             (1.0 - on.seconds_per_image() / off.seconds_per_image())
             * 100.0);
}
