//! Bench: regenerate Table II (resources, power, epoch latency vs batch
//! size, GOPS for CIFAR-10 1X/2X/4X) and print it next to the paper's
//! published rows.  `cargo bench --bench table2`

use std::time::Instant;

use stratus::metrics::table2;

// paper Table II reference rows:
// (name, dsp, alm_k, bram_mbit, bs10, bs20, bs40, gops)
const PAPER: &[(&str, u64, f64, f64, f64, f64, f64, f64)] = &[
    ("CIFAR-10 1X", 1699, 20.8, 10.6, 18.19, 18.07, 18.01, 163.0),
    ("CIFAR-10 2X", 3363, 41.5, 22.8, 41.70, 41.30, 41.00, 282.0),
    ("CIFAR-10 4X", 5760, 72.0, 54.5, 98.20, 96.87, 96.18, 479.0),
];

fn main() {
    let t0 = Instant::now();
    let ours = table2();
    let dt = t0.elapsed();
    println!("=== Table II (reproduced) ===");
    println!("{ours}");
    println!("=== Table II (paper) ===");
    for (name, dsp, alm, bram, b10, b20, b40, gops) in PAPER {
        println!(
            "{name}: DSP {dsp}, ALM {alm}K, BRAM {bram} Mbit, epoch \
             {b10}/{b20}/{b40} s (BS 10/20/40), {gops} GOPS"
        );
    }
    println!("\nregenerated in {:.1} ms", dt.as_secs_f64() * 1e3);
    println!("shape checks: GOPS ordering 1X<2X<4X, epoch ordering \
              1X<2X<4X, BS-40 slightly faster than BS-10 — asserted in \
              `cargo test` (sim::tests)");
}
