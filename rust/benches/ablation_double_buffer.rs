//! Ablation bench (§IV-B): double buffering hides DRAM latency behind
//! compute; the paper reports an ~11% reduction in weight-update-layer
//! latency.  `cargo bench --bench ablation_double_buffer`

use stratus::compiler::RtlCompiler;
use stratus::config::{DesignVars, Network};
use stratus::sim::simulate;

fn main() {
    println!("=== double-buffering ablation ===");
    println!("{:<6} {:>16} {:>16} {:>10} {:>12}", "net",
             "WU latency (on)", "WU latency (off)", "WU gain",
             "image gain");
    for scale in [1usize, 2, 4] {
        let net = Network::cifar(scale);
        let mut dv = DesignVars::for_scale(scale);
        let on = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        dv.double_buffer = false;
        let off = simulate(
            &RtlCompiler::default().compile(&net, &dv).unwrap(), 40);
        let wu_gain = 1.0
            - on.wu.latency_cycles as f64 / off.wu.latency_cycles as f64;
        let img_gain = 1.0 - on.cycles_per_image() / off.cycles_per_image();
        println!("{:<6} {:>16} {:>16} {:>9.1}% {:>11.1}%",
                 format!("{scale}X"), on.wu.latency_cycles,
                 off.wu.latency_cycles, wu_gain * 100.0,
                 img_gain * 100.0);
    }
    println!("\npaper §IV-B: double buffering reduced weight-update-layer \
              latency by 11%");
}
